//! Dependency-free HTTP/1.1 front-end over the streaming session API
//! (`slab serve --http <addr>`): pure `std::net`, no async runtime,
//! no TLS, no external crates — a thread-per-connection JSON server
//! sized for this testbed and its benches (DESIGN.md §12).
//!
//! Wire surface:
//!
//! * `POST /v1/generate` — body
//!   `{"prompt": [ints], "max_new": n, "stream": bool, "deadline_ms": ms}`
//!   (`deadline_ms` of `0` or omitted = no per-request deadline, the
//!   same convention as `--deadline-ms` and
//!   [`SchedulerConfig::deadline`](super::serve::SchedulerConfig)).
//!   Non-streaming: one JSON object with the whole completion
//!   (`Session::collect` semantics). Streaming (`"stream": true`):
//!   SSE-style chunked transfer — one `data: {...}\n\n` frame per
//!   [`Event`], starting with `{"id": n}` so the client can cancel.
//! * `DELETE /v1/sessions/{id}` — cancel a live session mid-stream;
//!   its KV slot frees immediately and the stream terminates with
//!   `{"done": {..., "cancelled": true}}`.
//! * `GET /healthz` — liveness probe.
//! * `GET /metrics` — the live [`ServeStats`] snapshot rendered
//!   through [`report::Table`](crate::report::Table) (text/plain),
//!   including the paged-KV gauges (`kv_pages`, `kv_pages_peak`) and
//!   prefix-cache counters (`prefix_hits` / `prefix_misses` /
//!   `prefix_hit_rate`, `cow_splits`, `page_evictions`) of
//!   DESIGN.md §13, and the speculative-decode counters
//!   (`spec_rounds`, `spec_drafted`, `spec_accepted`,
//!   `spec_acceptance_rate`, `spec_rollbacks`) of DESIGN.md §14.
//!
//! A client that disconnects mid-stream is treated as a cancellation
//! (the router stops decoding for it); a malformed request gets a
//! `400` and never reaches the engine. The [`client`] submodule holds
//! the minimal blocking loopback client the benches and integration
//! tests drive this server with.

use super::serve::{CancelHandle, Event, Request, Server, SessionStats};
use crate::runtime::client::RuntimeError;
use crate::util::json::Json;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Read/write guards on connection sockets so a stalled client —
/// one that stops sending *or* stops reading its stream — cannot pin
/// a handler thread (a timed-out write cancels the session like any
/// other hang-up).
const READ_TIMEOUT: Duration = Duration::from_secs(30);
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);
/// Request-body cap — far above any prompt this testbed serves.
const MAX_BODY: usize = 1 << 20;
/// Per-line cap for the request line and each header, and a header
/// count cap: a client streaming newline-free bytes must hit a bound,
/// not grow a String until the read timeout.
const MAX_LINE: usize = 8 * 1024;
const MAX_HEADERS: usize = 64;

/// State shared by the accept loop and every connection handler.
struct HttpState {
    /// The serving router. `None` after shutdown — handlers answer
    /// `503` instead of panicking on a vanished server.
    server: Mutex<Option<Server>>,
    /// Live sessions by id — the `DELETE /v1/sessions/{id}` registry.
    sessions: Mutex<HashMap<u64, CancelHandle>>,
    running: AtomicBool,
    started: Instant,
}

impl HttpState {
    fn lock_sessions(&self) -> std::sync::MutexGuard<'_, HashMap<u64, CancelHandle>> {
        self.sessions.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn lock_server(&self) -> std::sync::MutexGuard<'_, Option<Server>> {
        self.server.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// The HTTP front-end handle: owns the accept loop and the inner
/// [`Server`]. Bind, then either [`serve_forever`](HttpServer::serve_forever)
/// (the CLI) or drive it from tests/benches and
/// [`shutdown`](HttpServer::shutdown).
pub struct HttpServer {
    addr: SocketAddr,
    state: Arc<HttpState>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:8080`, or port `0` for an
    /// ephemeral port — see [`addr`](HttpServer::addr)) and start the
    /// accept loop over `server`. Any [`Backend`](super::serve::Backend)
    /// works — the front-end only speaks the session API.
    pub fn bind(addr: &str, server: Server) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let state = Arc::new(HttpState {
            server: Mutex::new(Some(server)),
            sessions: Mutex::new(HashMap::new()),
            running: AtomicBool::new(true),
            started: Instant::now(),
        });
        let accept_state = state.clone();
        let accept = std::thread::Builder::new()
            .name("slab-http".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if !accept_state.running.load(Ordering::Acquire) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let conn_state = accept_state.clone();
                    // Connection threads are detached: they end with
                    // their connection, and shutdown() cancels any
                    // session they might still be streaming.
                    let _ = std::thread::Builder::new()
                        .name("slab-http-conn".into())
                        .spawn(move || handle_connection(stream, &conn_state));
                }
            })
            .expect("spawn http accept loop");
        Ok(HttpServer {
            addr: local,
            state,
            accept: Some(accept),
        })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block the calling thread on the accept loop — the CLI's
    /// serve-until-killed mode.
    pub fn serve_forever(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting, cancel in-flight sessions, and shut the inner
    /// [`Server`] down, returning its aggregate stats.
    pub fn shutdown(mut self) -> Result<super::serve::ServeStats, RuntimeError> {
        self.state.running.store(false, Ordering::Release);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // Take the server *before* the cancel sweep: handlers that
        // race this point see `None` (503) and cannot submit past the
        // sweep; a handler that already submitted either lands in the
        // registry before the sweep (cancelled here) or observes
        // `running == false` right after registering and cancels
        // itself (see `handle_generate`).
        let server = self.state.lock_server().take();
        for (_, cancel) in self.state.lock_sessions().drain() {
            cancel.cancel();
        }
        match server {
            Some(s) => s.shutdown(),
            None => Err(RuntimeError::Router("http server already shut down".into())),
        }
    }
}

// ---------------------------------------------------------------------
// Connection handling
// ---------------------------------------------------------------------

struct HttpRequest {
    method: String,
    path: String,
    body: String,
}

/// One connection, one request, one response (`Connection: close`) —
/// the simplest correct HTTP/1.1 subset; curl, the benches, and the
/// integration tests all speak it.
fn handle_connection(mut stream: TcpStream, state: &Arc<HttpState>) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let Ok(reader_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(reader_half);
    match read_request(&mut reader) {
        Ok(Some(req)) => route(&req, &mut stream, state),
        Ok(None) => {} // client connected and closed (shutdown poke)
        Err(msg) => {
            let body = Json::obj(vec![("error", Json::str(msg))]).to_string();
            let _ = write_response(&mut stream, 400, "Bad Request", "application/json", &body);
        }
    }
}

/// One request/header line, bounded at [`MAX_LINE`] bytes (a line
/// that long without a newline is an attack or a bug, never a valid
/// request of ours). `Ok(None)` on a clean EOF before any byte.
fn read_line_bounded(
    reader: &mut BufReader<TcpStream>,
    what: &str,
) -> Result<Option<String>, String> {
    let mut line = String::new();
    let mut limited = reader.by_ref().take(MAX_LINE as u64);
    match limited.read_line(&mut line) {
        Ok(0) => Ok(None),
        Ok(_) => {
            if !line.ends_with('\n') && line.len() >= MAX_LINE {
                return Err(format!("{what} exceeds {MAX_LINE} bytes"));
            }
            Ok(Some(line))
        }
        Err(e) => Err(format!("read {what}: {e}")),
    }
}

/// Parse request line, headers, and a `Content-Length` body.
/// `Ok(None)` when the client closed without sending anything.
fn read_request(reader: &mut BufReader<TcpStream>) -> Result<Option<HttpRequest>, String> {
    let Some(line) = read_line_bounded(reader, "request line")? else {
        return Ok(None);
    };
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_uppercase();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || !path.starts_with('/') {
        return Err("malformed request line".into());
    }
    let mut content_length = 0usize;
    for n_headers in 0.. {
        if n_headers >= MAX_HEADERS {
            return Err(format!("more than {MAX_HEADERS} headers"));
        }
        let Some(h) = read_line_bounded(reader, "header")? else {
            return Err("unexpected eof in headers".into());
        };
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .map_err(|_| "bad content-length".to_string())?;
            }
        }
    }
    if content_length > MAX_BODY {
        return Err(format!("body {content_length} exceeds cap {MAX_BODY}"));
    }
    let mut body = vec![0u8; content_length];
    reader
        .read_exact(&mut body)
        .map_err(|e| format!("read body: {e}"))?;
    let body = String::from_utf8(body).map_err(|_| "body is not utf-8".to_string())?;
    Ok(Some(HttpRequest { method, path, body }))
}

fn route(req: &HttpRequest, stream: &mut TcpStream, state: &Arc<HttpState>) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let body = Json::obj(vec![
                ("status", Json::str("ok")),
                (
                    "uptime_secs",
                    Json::num(state.started.elapsed().as_secs_f64()),
                ),
            ])
            .to_string();
            let _ = write_response(stream, 200, "OK", "application/json", &body);
        }
        ("GET", "/metrics") => {
            let stats = state.lock_server().as_ref().map(|s| s.stats());
            match stats {
                Some(stats) => {
                    let body = stats.table("serve metrics").render();
                    let _ = write_response(stream, 200, "OK", "text/plain; charset=utf-8", &body);
                }
                None => {
                    let _ = write_response(stream, 503, "Service Unavailable", "text/plain", "shutting down");
                }
            }
        }
        ("POST", "/v1/generate") => handle_generate(req, stream, state),
        ("DELETE", path) if path.starts_with("/v1/sessions/") => {
            handle_cancel(path, stream, state);
        }
        (_, "/healthz") | (_, "/metrics") | (_, "/v1/generate") => {
            let body = Json::obj(vec![("error", Json::str("method not allowed"))]).to_string();
            let _ = write_response(stream, 405, "Method Not Allowed", "application/json", &body);
        }
        _ => {
            let body = Json::obj(vec![("error", Json::str("not found"))]).to_string();
            let _ = write_response(stream, 404, "Not Found", "application/json", &body);
        }
    }
}

/// Parsed `POST /v1/generate` body.
struct GenerateBody {
    req: Request,
    stream: bool,
}

fn parse_generate(body: &str) -> Result<GenerateBody, String> {
    let v = Json::parse(body).map_err(|e| format!("bad json: {e}"))?;
    let prompt_json = v.get("prompt");
    let arr = prompt_json
        .as_arr()
        .ok_or_else(|| "missing or non-array 'prompt'".to_string())?;
    let mut prompt = Vec::with_capacity(arr.len());
    for item in arr {
        let tok = item
            .as_i64()
            .and_then(|t| i32::try_from(t).ok())
            .ok_or_else(|| "prompt entries must be i32 integers".to_string())?;
        prompt.push(tok);
    }
    let max_new = match v.get("max_new") {
        Json::Null => 16,
        n => n
            .as_usize()
            .ok_or_else(|| "'max_new' must be a non-negative integer".to_string())?,
    };
    let stream = match v.get("stream") {
        Json::Null => false,
        b => b
            .as_bool()
            .ok_or_else(|| "'stream' must be a boolean".to_string())?,
    };
    let deadline = match v.get("deadline_ms") {
        Json::Null => None,
        n => {
            let ms = n
                .as_f64()
                .filter(|ms| *ms >= 0.0)
                .ok_or_else(|| "'deadline_ms' must be a non-negative number".to_string())?;
            if ms == 0.0 {
                // Same convention as `--deadline-ms 0` and
                // `SchedulerConfig::deadline`: zero disables the
                // deadline (the expire-immediately form exists only
                // on the in-process `Request::deadline` API).
                None
            } else {
                // try_from: a finite-but-huge value must be a 400,
                // not a panic in the connection handler.
                let d = Duration::try_from_secs_f64(ms / 1e3)
                    .map_err(|_| "'deadline_ms' out of range".to_string())?;
                Some(d)
            }
        }
    };
    Ok(GenerateBody {
        req: Request {
            prompt,
            max_new,
            deadline,
        },
        stream,
    })
}

fn handle_generate(req: &HttpRequest, stream: &mut TcpStream, state: &Arc<HttpState>) {
    let parsed = match parse_generate(&req.body) {
        Ok(p) => p,
        Err(msg) => {
            let body = Json::obj(vec![("error", Json::str(msg))]).to_string();
            let _ = write_response(stream, 400, "Bad Request", "application/json", &body);
            return;
        }
    };
    // Submit while holding the server lock only for the enqueue
    // itself; the stream is consumed lock-free.
    let session = match state.lock_server().as_ref() {
        Some(server) => server.submit(parsed.req),
        None => {
            let _ = write_response(stream, 503, "Service Unavailable", "text/plain", "shutting down");
            return;
        }
    };
    let id = session.id();
    state.lock_sessions().insert(id, session.cancel_handle());
    // Shutdown race: if the cancel sweep ran between our submit and
    // this registration, the registry lock we just went through makes
    // the `running` store visible — self-cancel so no session can
    // outlive shutdown uncancelled.
    if !state.running.load(Ordering::Acquire) {
        session.cancel();
    }
    if parsed.stream {
        stream_events(stream, id, &session);
    } else {
        let r = session.collect();
        let body = Json::obj(vec![
            ("id", Json::from_usize(id as usize)),
            ("tokens", Json::arr(r.tokens.iter().map(|&t| Json::num(t)))),
            ("queue_ms", Json::num(r.queue_ms)),
            ("latency_ms", Json::num(r.latency_ms)),
            ("ttft_ms", Json::num(r.ttft_ms)),
            ("rejected", Json::Bool(r.rejected)),
            ("evicted", Json::Bool(r.evicted)),
            ("cancelled", Json::Bool(r.cancelled)),
            ("incomplete", Json::Bool(r.incomplete)),
        ])
        .to_string();
        if r.rejected {
            let _ = write_response(stream, 429, "Too Many Requests", "application/json", &body);
        } else if r.incomplete {
            // The router died mid-session; the tokens are truncated.
            let _ =
                write_response(stream, 500, "Internal Server Error", "application/json", &body);
        } else {
            let _ = write_response(stream, 200, "OK", "application/json", &body);
        }
    }
    state.lock_sessions().remove(&id);
}

/// SSE-style chunked token streaming: one `data: {...}\n\n` frame per
/// event, opening with `{"id": n}` so the client can `DELETE` the
/// session mid-stream. A client hang-up cancels the session — the
/// router must not keep decoding for a socket nobody reads.
fn stream_events(stream: &mut TcpStream, id: u64, session: &super::serve::Session) {
    let header = "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nTransfer-Encoding: chunked\r\nCache-Control: no-store\r\nConnection: close\r\n\r\n";
    if stream.write_all(header.as_bytes()).is_err() {
        session.cancel();
        return;
    }
    let opening = Json::obj(vec![("id", Json::from_usize(id as usize))]);
    if write_frame(stream, &opening).is_err() {
        session.cancel();
        return;
    }
    let mut saw_terminal = false;
    while let Some(ev) = session.recv() {
        let (frame, terminal) = match ev {
            Event::Token(t) => (Json::obj(vec![("token", Json::num(t))]), false),
            Event::Done(s) => (Json::obj(vec![("done", stats_json(&s))]), true),
            Event::Evicted(s) => (Json::obj(vec![("evicted", stats_json(&s))]), true),
            Event::Rejected => (Json::obj(vec![("rejected", Json::Bool(true))]), true),
        };
        if write_frame(stream, &frame).is_err() {
            session.cancel();
            return;
        }
        if terminal {
            saw_terminal = true;
            break;
        }
    }
    if !saw_terminal {
        // The stream closed with no terminal event: the router died
        // mid-session. Tell the client explicitly — a truncated token
        // stream must not read as a completed one.
        let aborted = Json::obj(vec![("aborted", Json::Bool(true))]);
        let _ = write_frame(stream, &aborted);
    }
    // Terminal chunk.
    let _ = stream.write_all(b"0\r\n\r\n");
}

fn stats_json(s: &SessionStats) -> Json {
    Json::obj(vec![
        ("tokens", Json::from_usize(s.tokens)),
        ("queue_ms", Json::num(s.queue_ms)),
        ("latency_ms", Json::num(s.latency_ms)),
        ("ttft_ms", Json::num(s.ttft_ms)),
        ("cancelled", Json::Bool(s.cancelled)),
    ])
}

/// One SSE frame as one HTTP chunk, flushed immediately — that is the
/// whole point of streaming.
fn write_frame(stream: &mut TcpStream, payload: &Json) -> std::io::Result<()> {
    let data = format!("data: {payload}\n\n");
    write!(stream, "{:x}\r\n{data}\r\n", data.len())?;
    stream.flush()
}

fn handle_cancel(path: &str, stream: &mut TcpStream, state: &Arc<HttpState>) {
    let id_str = path.trim_start_matches("/v1/sessions/");
    let Ok(id) = id_str.parse::<u64>() else {
        let body = Json::obj(vec![("error", Json::str("bad session id"))]).to_string();
        let _ = write_response(stream, 400, "Bad Request", "application/json", &body);
        return;
    };
    let handle = state.lock_sessions().get(&id).cloned();
    match handle {
        Some(cancel) => {
            cancel.cancel();
            let body = Json::obj(vec![
                ("id", Json::from_usize(id as usize)),
                ("cancelled", Json::Bool(true)),
            ])
            .to_string();
            let _ = write_response(stream, 200, "OK", "application/json", &body);
        }
        None => {
            let body =
                Json::obj(vec![("error", Json::str("unknown or finished session"))]).to_string();
            let _ = write_response(stream, 404, "Not Found", "application/json", &body);
        }
    }
}

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    ctype: &str,
    body: &str,
) -> std::io::Result<()> {
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()
}

// ---------------------------------------------------------------------
// Loopback client (benches / integration tests / examples)
// ---------------------------------------------------------------------

/// Minimal blocking HTTP client for the loopback surface above — just
/// enough protocol for the benches and integration tests to drive
/// `slab serve --http` over a real socket without external crates.
pub mod client {
    use super::super::serve::Response;
    use crate::util::json::Json;
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::{SocketAddr, TcpStream};
    use std::time::Duration;

    /// A completed (non-streaming) HTTP exchange.
    pub struct HttpReply {
        pub status: u16,
        pub body: String,
    }

    fn connect(addr: SocketAddr) -> std::io::Result<TcpStream> {
        let stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        Ok(stream)
    }

    fn read_status_and_headers(
        reader: &mut BufReader<TcpStream>,
    ) -> std::io::Result<(u16, bool, usize)> {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or(0);
        let mut chunked = false;
        let mut content_length = 0usize;
        loop {
            let mut h = String::new();
            if reader.read_line(&mut h)? == 0 {
                break;
            }
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((name, value)) = h.split_once(':') {
                let name = name.trim().to_ascii_lowercase();
                let value = value.trim();
                if name == "transfer-encoding" && value.eq_ignore_ascii_case("chunked") {
                    chunked = true;
                }
                if name == "content-length" {
                    content_length = value.parse().unwrap_or(0);
                }
            }
        }
        Ok((status, chunked, content_length))
    }

    /// One chunk of a chunked response body; `None` at the terminal
    /// zero-length chunk. A malformed or missing size line (server
    /// died mid-stream, truncated read) is an **error**, never
    /// mistaken for the clean terminal chunk.
    fn read_chunk(reader: &mut BufReader<TcpStream>) -> std::io::Result<Option<String>> {
        let mut size_line = String::new();
        reader.read_line(&mut size_line)?;
        let trimmed = size_line.trim();
        let size = usize::from_str_radix(trimmed, 16).map_err(|_| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("bad chunk size line {trimmed:?} (stream truncated?)"),
            )
        })?;
        if size == 0 {
            return Ok(None);
        }
        let mut payload = vec![0u8; size];
        reader.read_exact(&mut payload)?;
        let mut crlf = [0u8; 2];
        reader.read_exact(&mut crlf)?;
        Ok(Some(String::from_utf8_lossy(&payload).into_owned()))
    }

    /// Serialize one request (line + headers + body) — the single
    /// place the client-side wire framing lives.
    fn write_request(
        stream: &mut TcpStream,
        method: &str,
        path: &str,
        body: &str,
    ) -> std::io::Result<()> {
        write!(
            stream,
            "{method} {path} HTTP/1.1\r\nHost: slab\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )?;
        stream.flush()
    }

    /// Send `method path` with an optional JSON body; return the
    /// fully-read reply (chunked bodies are de-chunked).
    pub fn request(
        addr: SocketAddr,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> std::io::Result<HttpReply> {
        let mut stream = connect(addr)?;
        write_request(&mut stream, method, path, body.unwrap_or(""))?;
        let mut reader = BufReader::new(stream);
        let (status, chunked, content_length) = read_status_and_headers(&mut reader)?;
        let body = if chunked {
            let mut out = String::new();
            while let Some(chunk) = read_chunk(&mut reader)? {
                out.push_str(&chunk);
            }
            out
        } else if content_length > 0 {
            let mut buf = vec![0u8; content_length];
            reader.read_exact(&mut buf)?;
            String::from_utf8_lossy(&buf).into_owned()
        } else {
            let mut out = String::new();
            reader.read_to_string(&mut out)?;
            out
        };
        Ok(HttpReply { status, body })
    }

    pub fn get(addr: SocketAddr, path: &str) -> std::io::Result<HttpReply> {
        request(addr, "GET", path, None)
    }

    pub fn post(addr: SocketAddr, path: &str, body: &str) -> std::io::Result<HttpReply> {
        request(addr, "POST", path, Some(body))
    }

    pub fn delete(addr: SocketAddr, path: &str) -> std::io::Result<HttpReply> {
        request(addr, "DELETE", path, None)
    }

    /// An open SSE token stream (a `POST /v1/generate` with
    /// `"stream": true`): read frames one at a time, cancel from
    /// another connection, keep reading — exactly what an interactive
    /// client does.
    pub struct SseStream {
        reader: BufReader<TcpStream>,
        pub status: u16,
    }

    impl SseStream {
        pub fn open(addr: SocketAddr, body: &str) -> std::io::Result<SseStream> {
            let mut stream = connect(addr)?;
            write_request(&mut stream, "POST", "/v1/generate", body)?;
            let mut reader = BufReader::new(stream);
            let (status, _, _) = read_status_and_headers(&mut reader)?;
            Ok(SseStream { reader, status })
        }

        /// Next `data:` frame parsed as JSON; `None` once the stream
        /// is over.
        pub fn next_frame(&mut self) -> std::io::Result<Option<Json>> {
            let Some(chunk) = read_chunk(&mut self.reader)? else {
                return Ok(None);
            };
            let payload = chunk
                .trim_start_matches("data: ")
                .trim_end_matches('\n')
                .to_string();
            let v = Json::parse(&payload).map_err(|e| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad sse frame {payload:?}: {e}"),
                )
            })?;
            Ok(Some(v))
        }
    }

    /// Parse a non-streaming `POST /v1/generate` reply body into the
    /// blocking [`Response`] shape (token-identity checks in tests).
    pub fn parse_generate_reply(body: &str) -> Option<(u64, Response)> {
        let v = Json::parse(body).ok()?;
        let id = v.get("id").as_i64()? as u64;
        let tokens = v
            .get("tokens")
            .as_arr()?
            .iter()
            .map(|t| t.as_i64().map(|x| x as i32))
            .collect::<Option<Vec<i32>>>()?;
        Some((
            id,
            Response {
                tokens,
                queue_ms: v.get("queue_ms").as_f64().unwrap_or(0.0),
                latency_ms: v.get("latency_ms").as_f64().unwrap_or(0.0),
                ttft_ms: v.get("ttft_ms").as_f64().unwrap_or(0.0),
                rejected: v.get("rejected").as_bool().unwrap_or(false),
                evicted: v.get("evicted").as_bool().unwrap_or(false),
                cancelled: v.get("cancelled").as_bool().unwrap_or(false),
                incomplete: v.get("incomplete").as_bool().unwrap_or(false),
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    //! Loopback unit tests: every route over a real socket, native
    //! engine, no artifacts — they run on every `cargo test`.

    use super::client;
    use super::*;
    use crate::coordinator::serve::test_support::eos_free_params;
    use crate::coordinator::serve::{Backend, SchedulerConfig, ServerConfig};
    use crate::model::{Params, SlabModel};
    use crate::runtime::ModelCfg;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg::llama("tiny-http", 32, 8, 1, 2, 16, 12, 4)
    }

    fn spin(cfg: &ModelCfg, seed: u64, scfg: ServerConfig) -> HttpServer {
        let model = SlabModel::from_dense(&Params::init(cfg, seed), 1);
        let server = Server::start_with(Backend::NativeBatched(Box::new(model)), scfg);
        HttpServer::bind("127.0.0.1:0", server).expect("bind loopback")
    }

    #[test]
    fn healthz_metrics_and_unknown_routes() {
        let http = spin(&tiny_cfg(), 81, ServerConfig::default());
        let addr = http.addr();
        let ok = client::get(addr, "/healthz").expect("healthz");
        assert_eq!(ok.status, 200);
        assert!(ok.body.contains("\"status\":\"ok\""), "{}", ok.body);
        let metrics = client::get(addr, "/metrics").expect("metrics");
        assert_eq!(metrics.status, 200);
        assert!(metrics.body.contains("requests"), "{}", metrics.body);
        assert!(metrics.body.contains("mean_ttft_ms"), "{}", metrics.body);
        assert!(metrics.body.contains("prefix_hit_rate"), "{}", metrics.body);
        assert!(metrics.body.contains("kv_pages"), "{}", metrics.body);
        assert!(metrics.body.contains("spec_acceptance_rate"), "{}", metrics.body);
        let missing = client::get(addr, "/nope").expect("404");
        assert_eq!(missing.status, 404);
        let wrong_method = client::get(addr, "/v1/generate").expect("405");
        assert_eq!(wrong_method.status, 405);
        let bad_delete = client::delete(addr, "/v1/sessions/not-a-number").expect("400");
        assert_eq!(bad_delete.status, 400);
        let unknown_session = client::delete(addr, "/v1/sessions/999").expect("404");
        assert_eq!(unknown_session.status, 404);
        http.shutdown().expect("shutdown");
    }

    #[test]
    fn generate_rejects_malformed_bodies() {
        let http = spin(&tiny_cfg(), 82, ServerConfig::default());
        let addr = http.addr();
        for bad in [
            "not json at all",
            "{}",                         // missing prompt
            r#"{"prompt": "text"}"#,      // non-array prompt
            r#"{"prompt": [1.5]}"#,       // non-integer token
            r#"{"prompt": [5000000000]}"#, // out of i32 range
            r#"{"prompt": [5], "max_new": -2}"#,
            r#"{"prompt": [5], "stream": "yes"}"#,
            r#"{"prompt": [5], "deadline_ms": -1}"#,
            // Finite but not representable as a Duration: must be a
            // 400, not a panic in the connection handler.
            r#"{"prompt": [5], "deadline_ms": 1e300}"#,
        ] {
            let reply = client::post(addr, "/v1/generate", bad).expect("reply");
            assert_eq!(reply.status, 400, "body {bad:?} → {}", reply.body);
        }
        // The server is still healthy afterwards.
        let ok = client::post(addr, "/v1/generate", r#"{"prompt": [5, 6], "max_new": 3}"#)
            .expect("good request");
        assert_eq!(ok.status, 200);
        let stats = http.shutdown().expect("shutdown");
        assert_eq!(stats.requests, 1, "malformed bodies never reach the engine");
    }

    #[test]
    fn streamed_tokens_equal_blocking_generate() {
        let cfg = tiny_cfg();
        let http = spin(&cfg, 83, ServerConfig::default());
        let addr = http.addr();
        let body = r#"{"prompt": [5, 6, 7], "max_new": 6}"#;
        let blocking = client::post(addr, "/v1/generate", body).expect("blocking");
        assert_eq!(blocking.status, 200);
        let (_, reply) = client::parse_generate_reply(&blocking.body).expect("parse");
        assert!(!reply.rejected);

        let stream_body = r#"{"prompt": [5, 6, 7], "max_new": 6, "stream": true}"#;
        let mut sse = client::SseStream::open(addr, stream_body).expect("open stream");
        assert_eq!(sse.status, 200);
        let first = sse.next_frame().expect("frame").expect("id frame");
        assert!(first.get("id").as_i64().is_some(), "{first:?}");
        let mut streamed = Vec::new();
        let mut saw_done = false;
        while let Some(frame) = sse.next_frame().expect("frame") {
            if let Some(tok) = frame.get("token").as_i64() {
                streamed.push(tok as i32);
            } else if !frame.get("done").is_null() {
                assert_eq!(
                    frame.get("done").get("tokens").as_usize(),
                    Some(streamed.len())
                );
                saw_done = true;
            } else {
                panic!("unexpected frame {frame:?}");
            }
        }
        assert!(saw_done, "stream must end with a done frame");
        assert_eq!(streamed, reply.tokens, "streamed vs blocking tokens");
        http.shutdown().expect("shutdown");
    }

    #[test]
    fn delete_cancels_a_live_stream() {
        // Long-budget session on a deliberately slow config (dim 64,
        // ~1k ticks to finish): read two tokens, DELETE the session,
        // and the stream must terminate early with cancelled=true.
        let cfg = ModelCfg::llama("slow-http", 32, 64, 2, 2, 128, 1024, 4);
        let params = eos_free_params(&cfg, 84);
        let model = SlabModel::from_dense(&params, 1);
        let server = Server::start_with(
            Backend::NativeBatched(Box::new(model)),
            ServerConfig {
                sched: SchedulerConfig {
                    max_batch: 1,
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        let http = HttpServer::bind("127.0.0.1:0", server).expect("bind");
        let addr = http.addr();
        let budget = cfg.max_seq - cfg.prompt_len;
        let body = format!(r#"{{"prompt": [5, 6], "max_new": {budget}, "stream": true}}"#);
        let mut sse = client::SseStream::open(addr, &body).expect("open");
        let id = sse
            .next_frame()
            .expect("frame")
            .expect("id frame")
            .get("id")
            .as_i64()
            .expect("id") as u64;
        let mut tokens = 0usize;
        while tokens < 2 {
            let frame = sse.next_frame().expect("frame").expect("open stream");
            if frame.get("token").as_i64().is_some() {
                tokens += 1;
            } else {
                panic!("terminal before two tokens: {frame:?}");
            }
        }
        let cancel = client::delete(addr, &format!("/v1/sessions/{id}")).expect("cancel");
        assert_eq!(cancel.status, 200);
        let mut cancelled_seen = false;
        while let Some(frame) = sse.next_frame().expect("frame") {
            if frame.get("token").as_i64().is_some() {
                tokens += 1;
            } else if !frame.get("done").is_null() {
                assert_eq!(frame.get("done").get("cancelled").as_bool(), Some(true));
                cancelled_seen = true;
            }
        }
        assert!(cancelled_seen, "terminal frame carries cancelled=true");
        assert!(
            tokens < budget,
            "cancel must stop the stream early ({tokens} of {budget})"
        );
        let stats = http.shutdown().expect("shutdown");
        assert_eq!(stats.cancelled, 1);
        assert_eq!(stats.requests, 1, "the cancelled session still counts");
    }
}
