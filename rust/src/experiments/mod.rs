//! Experiment drivers — one function per paper table/figure.
//!
//! Shared by the `slab` CLI subcommands, the examples, and
//! `rust/benches/bench_tables.rs` so every surface regenerates
//! identical rows. See DESIGN.md §5 for the experiment index.

use crate::baselines::{Method, SparseGptConfig};
use crate::coordinator::{compress_model, BudgetConfig, CompressJob, Engine, PipelineError};
use crate::data::{build_corpus, CorpusBundle, Grammar, Task, TaskItem, ALL_TASKS};
use crate::eval::native::EvalOptions;
use crate::eval::{perplexity, zero_shot};
use crate::model::{Params, SlabModel};
use crate::report::Table;
use crate::runtime::{ModelCfg, Runtime};
use crate::slab::{GroupShape, RefineConfig, SlabConfig, Structure, Variant};
use crate::sparse::{PATTERN_2_4, PATTERN_4_8};
use crate::train::train;
use std::path::{Path, PathBuf};

/// Everything an experiment needs: runtime, corpora, task suites.
pub struct Lab {
    pub rt: Runtime,
    pub runs_dir: PathBuf,
    pub grammar: Grammar,
    pub seed: u64,
    pub task_items: usize,
}

pub const CORPUS_SEED: u64 = 42;
pub const TRAIN_ROWS: usize = 4096;
pub const VALID_ROWS: usize = 128;
pub const CALIB_ROWS: usize = 128;

impl Lab {
    pub fn new(artifacts: &Path, runs: &Path) -> anyhow::Result<Lab> {
        Ok(Lab {
            rt: Runtime::new(artifacts)?,
            runs_dir: runs.to_path_buf(),
            grammar: Grammar::standard(),
            seed: CORPUS_SEED,
            task_items: 40,
        })
    }

    pub fn corpus(&self, cfg_name: &str) -> CorpusBundle {
        let cfg = self.rt.manifest.config(cfg_name).expect("config");
        build_corpus(
            &self.grammar,
            self.seed,
            TRAIN_ROWS,
            VALID_ROWS,
            CALIB_ROWS,
            cfg.max_seq,
        )
    }

    pub fn suites(&self) -> Vec<(Task, Vec<TaskItem>)> {
        ALL_TASKS
            .iter()
            .map(|t| (*t, t.generate(&self.grammar, self.task_items, self.seed ^ 0x7a5c)))
            .collect()
    }

    fn ckpt_path(&self, cfg_name: &str) -> PathBuf {
        self.runs_dir.join(format!("{cfg_name}.slabckpt"))
    }

    /// Trained dense params for `cfg_name`: load the checkpoint if it
    /// exists, otherwise train now (the e2e driver path) and save.
    pub fn dense_params(&self, cfg_name: &str, steps: usize) -> anyhow::Result<Params> {
        let cfg = self
            .rt
            .manifest
            .config(cfg_name)
            .ok_or_else(|| anyhow::anyhow!("unknown config {cfg_name}"))?
            .clone();
        let path = self.ckpt_path(cfg_name);
        if path.exists() {
            return Ok(Params::load(&cfg, &path)?);
        }
        eprintln!("[lab] no checkpoint for '{cfg_name}' — training {steps} steps");
        let corpus = self.corpus(cfg_name);
        let init = Params::init(&cfg, self.seed ^ 0x1417);
        let (trained, report) = train(&self.rt, &init, &corpus.train, steps, self.seed, 20)?;
        std::fs::create_dir_all(&self.runs_dir)?;
        trained.save(&path)?;
        // Record the loss curve (EXPERIMENTS.md §e2e evidence).
        let mut t = Table::new(
            &format!(
                "Training loss — {cfg_name} ({} params, {:.0} tok/s)",
                cfg.n_params(),
                report.tokens_per_sec
            ),
            &["step", "loss"],
        );
        for (s, l) in &report.loss_curve {
            t.push_row(vec![s.to_string(), format!("{l:.4}")]);
        }
        t.append_to(&self.runs_dir.join(format!("train_{cfg_name}.md")))?;
        Ok(trained)
    }

    /// Default training budget per config (1-core CPU testbed).
    pub fn default_steps(&self, cfg_name: &str) -> usize {
        match cfg_name {
            "small" => 500,
            "base" => 350,
            _ => 250,
        }
    }
}

/// Compress with a method and evaluate ppl + zero-shot average.
pub fn compress_and_eval(
    lab: &Lab,
    dense: &Params,
    corpus: &CorpusBundle,
    suites: &[(Task, Vec<TaskItem>)],
    method: &Method,
    engine: Engine,
) -> anyhow::Result<(f64, f64, f64)> {
    let compressed = if matches!(method, Method::Dense) {
        dense.clone()
    } else {
        compress_model(&lab.rt, dense, &corpus.calib, method, engine)?.params
    };
    let ppl = perplexity(&lab.rt, &compressed, &corpus.valid)?;
    let (_, acc) = zero_shot(&lab.rt, &compressed, suites)?;
    Ok((ppl, acc, 0.0))
}

/// The Table-I method grid (paper §III-A4).
pub fn table1_settings() -> Vec<(String, Vec<Method>)> {
    let slab = |cr: f64, st: Structure| {
        Method::Slab(SlabConfig {
            cr,
            structure: st,
            ..Default::default()
        })
    };
    let sg = |s: f64, p| Method::SparseGpt {
        sparsity: s,
        pattern: p,
        cfg: SparseGptConfig::default(),
    };
    let wa = |s: f64, p| Method::Wanda {
        sparsity: s,
        pattern: p,
    };
    vec![
        ("Dense 0%".into(), vec![Method::Dense]),
        (
            "US (50%)".into(),
            vec![sg(0.5, None), wa(0.5, None), slab(0.5, Structure::Unstructured)],
        ),
        (
            "4:8 (50%)".into(),
            vec![
                sg(0.5, Some(PATTERN_4_8)),
                wa(0.5, Some(PATTERN_4_8)),
                slab(0.5, Structure::SemiStructured(PATTERN_4_8)),
            ],
        ),
        (
            "2:4 (50%)".into(),
            vec![
                sg(0.5, Some(PATTERN_2_4)),
                wa(0.5, Some(PATTERN_2_4)),
                slab(0.5, Structure::SemiStructured(PATTERN_2_4)),
            ],
        ),
        (
            "US (60%)".into(),
            vec![sg(0.6, None), wa(0.6, None), slab(0.6, Structure::Unstructured)],
        ),
        (
            "US (70%)".into(),
            vec![sg(0.7, None), wa(0.7, None), slab(0.7, Structure::Unstructured)],
        ),
        (
            "US (80%)".into(),
            vec![sg(0.8, None), wa(0.8, None), slab(0.8, Structure::Unstructured)],
        ),
    ]
}

/// Table I: perplexity + mean zero-shot accuracy per (model, method,
/// sparsity). `models`/`groups` subset for time-boxed runs.
pub fn table1(lab: &Lab, models: &[String], groups: &[String]) -> anyhow::Result<Table> {
    let mut table = Table::new(
        "Table I — perplexity (valid shard) and mean zero-shot accuracy (%)",
        &["Model", "Method", "Sparsity(CR)", "ppl↓", "acc↑"],
    );
    let suites = lab.suites();
    for model in models {
        let dense = lab.dense_params(model, lab.default_steps(model))?;
        let corpus = lab.corpus(model);
        for (label, methods) in table1_settings() {
            if !groups.is_empty() && !groups.iter().any(|g| label.contains(g.as_str())) {
                continue;
            }
            for m in methods {
                let engine = if matches!(m, Method::Slab(_)) {
                    Engine::Artifact
                } else {
                    Engine::Native
                };
                let t0 = std::time::Instant::now();
                let (ppl, acc, _) =
                    compress_and_eval(lab, &dense, &corpus, &suites, &m, engine)?;
                eprintln!(
                    "[table1] {model} {} {label}: ppl {:.3} acc {:.3} ({:.1}s)",
                    m.name(),
                    ppl,
                    acc,
                    t0.elapsed().as_secs_f64()
                );
                table.push_row(vec![
                    model.clone(),
                    m.name(),
                    label.clone(),
                    Table::metric(ppl),
                    Table::pct(acc),
                ]);
            }
        }
    }
    Ok(table)
}

/// Table II: comparison-group sweep + iteration sweep (base model,
/// US 50%). Group geometry runs on the native engine (group shape is
/// traced into the artifact at (1, Din)).
pub fn table2(lab: &Lab, model: &str) -> anyhow::Result<(Table, Table)> {
    let dense = lab.dense_params(model, lab.default_steps(model))?;
    let corpus = lab.corpus(model);
    let suites = lab.suites();
    let dim = lab.rt.manifest.config(model).unwrap().dim;

    let mut groups = Table::new(
        "Table II(a) — comparison group sweep (US 50%)",
        &["Group", "ppl↓", "acc↑"],
    );
    let shapes: Vec<(String, GroupShape)> = vec![
        (format!("(1, Din/32)"), GroupShape { rows: 1, cols: (dim / 32).max(1) }),
        (format!("(1, Din/16)"), GroupShape { rows: 1, cols: (dim / 16).max(1) }),
        ("(1, Din)".into(), GroupShape::PER_ROW),
        ("(16, Din)".into(), GroupShape { rows: 16, cols: 0 }),
        ("(32, Din)".into(), GroupShape { rows: 32, cols: 0 }),
    ];
    for (label, g) in shapes {
        let m = Method::Slab(SlabConfig {
            group: g,
            ..Default::default()
        });
        let (ppl, acc, _) = compress_and_eval(lab, &dense, &corpus, &suites, &m, Engine::Native)?;
        eprintln!("[table2a] {label}: ppl {ppl:.3} acc {acc:.3}");
        groups.push_row(vec![label, Table::metric(ppl), Table::pct(acc)]);
    }

    let mut iters = Table::new(
        "Table II(b) — iteration sweep (US 50%)",
        &["Iterations", "ppl↓"],
    );
    for s in [1usize, 10, 20, 30, 40] {
        let m = Method::Slab(SlabConfig {
            iters: s,
            ..Default::default()
        });
        let (ppl, _, _) = compress_and_eval(lab, &dense, &corpus, &suites, &m, Engine::Artifact)?;
        eprintln!("[table2b] iters {s}: ppl {ppl:.3}");
        iters.push_row(vec![s.to_string(), Table::metric(ppl)]);
    }
    Ok((groups, iters))
}

/// Table III: component ablation (2:4, CR 50%) on four tasks.
pub fn table3(lab: &Lab, model: &str) -> anyhow::Result<Table> {
    let dense = lab.dense_params(model, lab.default_steps(model))?;
    let corpus = lab.corpus(model);
    let tasks = [Task::ArcC, Task::ArcE, Task::Rte, Task::WinoGrande];
    let suites: Vec<(Task, Vec<TaskItem>)> = tasks
        .iter()
        .map(|t| (*t, t.generate(&lab.grammar, lab.task_items, lab.seed ^ 0x7a5c)))
        .collect();
    let mut table = Table::new(
        "Table III — ablation (2:4, CR 50%), accuracy (%)",
        &["Variant", "ARC-C", "ARC-E", "RTE", "WinoGrande", "Avg"],
    );
    let cfg24 = SlabConfig {
        structure: Structure::SemiStructured(PATTERN_2_4),
        ..Default::default()
    };
    for variant in [
        Variant::SparseOnly,
        Variant::SparseLowRank { rank: 16 },
        Variant::SparseFactorBinary,
        Variant::Full,
    ] {
        let m = Method::Ablation(cfg24, variant);
        let compressed = compress_model(&lab.rt, &dense, &corpus.calib, &m, Engine::Native)?;
        let (per_task, avg) = zero_shot(&lab.rt, &compressed.params, &suites)?;
        eprintln!("[table3] {}: avg {avg:.3}", variant.label());
        let mut row = vec![variant.label()];
        row.extend(per_task.iter().map(|(_, a)| Table::pct(*a)));
        row.push(Table::pct(avg));
        table.push_row(row);
    }
    Ok(table)
}

/// Fig. 1: naive sparse+low-rank at CR 50% — ppl vs rank.
pub fn fig1(lab: &Lab, model: &str, ranks: &[usize]) -> anyhow::Result<Table> {
    let dense = lab.dense_params(model, lab.default_steps(model))?;
    let corpus = lab.corpus(model);
    let suites = lab.suites();
    let mut table = Table::new(
        "Fig. 1 — naive sparse + rank-r low-rank at CR 50% (no binary)",
        &["rank", "ppl↓", "acc↑"],
    );
    for &r in ranks {
        let m = Method::LowrankSparse {
            cr: 0.5,
            rank: r,
            iters: 5,
        };
        match compress_and_eval(lab, &dense, &corpus, &suites, &m, Engine::Native) {
            Ok((ppl, acc, _)) => {
                eprintln!("[fig1] rank {r}: ppl {ppl:.3}");
                table.push_row(vec![r.to_string(), Table::metric(ppl), Table::pct(acc)]);
            }
            Err(e) => {
                eprintln!("[fig1] rank {r}: infeasible ({e})");
                table.push_row(vec![r.to_string(), "infeasible".into(), "-".into()]);
            }
        }
    }
    Ok(table)
}

// ---------------------------------------------------------------------------
// Artifact-free sweep: the paper's comparison matrix on the native engine
// ---------------------------------------------------------------------------

/// Configuration of the artifact-free compression/evaluation sweep
/// ([`sweep`]): which model shape, which ratios, and how much data /
/// parallelism. Everything here runs without XLA artifacts — the
/// corpus comes from the grammar, compression from [`CompressJob`]'s
/// native capture, and scoring from `eval::native` on the packed
/// serving engine.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Model shape ([`ModelCfg::llama`]); the task suites need
    /// `max_seq ≥ 47` (their longest prompt ⧺ option is 48 tokens)
    /// and `vocab ≥ Grammar::standard().vocab()`.
    pub model: ModelCfg,
    pub seed: u64,
    /// Compression ratios / sparsities to sweep (paper Table I's rows).
    pub ratios: Vec<f64>,
    /// Held-out perplexity shard rows.
    pub valid_rows: usize,
    /// Calibration rows fed to every compression job.
    pub calib_rows: usize,
    /// Items per zero-shot suite.
    pub task_items: usize,
    /// Worker threads for the compress fan-out and the eval-row
    /// fan-out: `1` serial, `0` available parallelism — bit-identical
    /// either way (the shared determinism contract).
    pub threads: usize,
    /// Eval rows per forward within one worker.
    pub eval_batch: usize,
    /// Algorithm-1 iterations for SLaB and the naive sparse+low-rank
    /// baseline (testbed-sized; the paper default is 20).
    pub iters: usize,
    /// Rank of the naive sparse+low-rank baseline (Fig. 1's knob).
    pub lowrank_rank: usize,
    /// Joint-refinement rounds for the sweep's `SLaB+refine` /
    /// `SLaB+alloc` rows (`crate::slab::refine`; 0 degenerates them to
    /// plain SLaB).
    pub refine_rounds: usize,
}

impl SweepConfig {
    /// A testbed-sized sweep that finishes in seconds: grammar-sized
    /// vocab, `max_seq` 48 (the task suites' row bound), two blocks.
    pub fn quick(seed: u64) -> SweepConfig {
        let vocab = Grammar::standard().vocab();
        SweepConfig {
            model: ModelCfg::llama("sweep", vocab, 48, 2, 4, 96, 48, 8),
            seed,
            ratios: vec![0.5, 0.6],
            valid_rows: 16,
            calib_rows: 8,
            task_items: 8,
            threads: 0,
            eval_batch: 8,
            iters: 8,
            lowrank_rank: 2,
            refine_rounds: 2,
        }
    }
}

/// The method grid one sweep ratio compares — SLaB against the four
/// baselines the repo carries (paper §III-A4 / Fig. 1), all
/// unstructured at sparsity/CR `cr`.
pub fn sweep_methods(scfg: &SweepConfig, cr: f64) -> Vec<Method> {
    vec![
        Method::Slab(SlabConfig {
            cr,
            iters: scfg.iters,
            ..Default::default()
        }),
        Method::Wanda {
            sparsity: cr,
            pattern: None,
        },
        Method::SparseGpt {
            sparsity: cr,
            pattern: None,
            cfg: SparseGptConfig::default(),
        },
        Method::Magnitude {
            sparsity: cr,
            pattern: None,
        },
        Method::LowrankSparse {
            cr,
            rank: scfg.lowrank_rank,
            iters: scfg.iters,
        },
    ]
}

/// Shared setup of the artifact-free paths: validate the model shape
/// against the grammar and task suites, then build the corpus splits
/// (the same derivation as `Lab::corpus`; the train split is unused)
/// and the seven task suites.
fn native_eval_setup(
    scfg: &SweepConfig,
    cfg: &ModelCfg,
) -> anyhow::Result<(CorpusBundle, Vec<(Task, Vec<TaskItem>)>)> {
    let g = Grammar::standard();
    anyhow::ensure!(
        g.vocab() <= cfg.vocab,
        "model vocab {} smaller than grammar vocab {}",
        cfg.vocab,
        g.vocab()
    );
    anyhow::ensure!(
        cfg.max_seq >= 47,
        "task suites need max_seq ≥ 47, got {}",
        cfg.max_seq
    );
    let corpus = build_corpus(&g, scfg.seed, 1, scfg.valid_rows, scfg.calib_rows, cfg.max_seq);
    let suites: Vec<(Task, Vec<TaskItem>)> = ALL_TASKS
        .iter()
        .map(|t| (*t, t.generate(&g, scfg.task_items, scfg.seed ^ 0x7a5c)))
        .collect();
    Ok((corpus, suites))
}

/// Artifact-free single-model evaluation: perplexity plus the seven
/// zero-shot suites on the native engine, optionally compressing with
/// `method` first (native capture + `threads` fan-out; SLaB is served
/// straight out of the packed format). The `slab eval --engine
/// native` surface.
pub fn eval_native_table(
    scfg: &SweepConfig,
    params: &Params,
    method: Option<&Method>,
) -> anyhow::Result<Table> {
    let cfg = &params.cfg;
    let (corpus, suites) = native_eval_setup(scfg, cfg)?;
    let opts = EvalOptions {
        batch: scfg.eval_batch,
        threads: scfg.threads,
    };
    let (model, label) = match method {
        Some(m) if !matches!(m, Method::Dense) => {
            let out = CompressJob::new(params, &corpus.calib, m)
                .threads(scfg.threads)
                .run()?;
            let model = out
                .serving_model(params, 1)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            (model, format!("{} {}", m.name(), m.sparsity_label()))
        }
        _ => (SlabModel::from_dense(params, 1), "Dense".to_string()),
    };
    let ppl = crate::eval::native::perplexity(&model, &corpus.valid, opts);
    let (per_task, avg) = crate::eval::native::zero_shot(&model, &suites, opts);
    let mut t = Table::new(
        &format!(
            "Evaluation — {} / {label} (native engine, {} packed linears, no artifacts)",
            cfg.name,
            model.packed_linear_count()
        ),
        &["metric", "value"],
    );
    t.push_row(vec!["perplexity".into(), Table::metric(ppl)]);
    for (task, acc) in per_task {
        t.push_row(vec![task.name().into(), Table::pct(acc)]);
    }
    t.push_row(vec!["avg acc".into(), Table::pct(avg)]);
    Ok(t)
}

/// The paper-style results table, end to end on the native engine:
/// compress `params` at every ratio with SLaB and the four baselines
/// (native capture, `threads` fan-out), serve each result natively
/// (SLaB straight out of the packed format, baselines via their dense
/// reconstruction), and score perplexity + the seven zero-shot suites
/// through `eval::native` — **no XLA artifacts anywhere**. Each ratio
/// also carries two SLaB variants at the *same* global parameter
/// budget: `SLaB+refine` (joint refinement of the uniform allocation,
/// [`crate::slab::refine`]) and `SLaB+alloc` (refinement on top of the
/// activation-aware water-filled budget,
/// [`crate::coordinator::budget`]). Rows the budget cannot realize
/// (e.g. an infeasible low-rank allocation) render as `infeasible`
/// instead of aborting the sweep.
pub fn sweep(scfg: &SweepConfig, params: &Params) -> anyhow::Result<Table> {
    let cfg = &params.cfg;
    let (corpus, suites) = native_eval_setup(scfg, cfg)?;
    let opts = EvalOptions {
        batch: scfg.eval_batch,
        threads: scfg.threads,
    };

    let mut header: Vec<&str> = vec!["Method", "Sparsity(CR)", "ppl↓"];
    header.extend(ALL_TASKS.iter().map(|t| t.name()));
    header.push("acc↑");
    let mut table = Table::new(
        &format!(
            "Sweep — SLaB vs baselines on the native packed engine \
             ({}: {} params, {} valid rows, {} items/task)",
            cfg.name,
            cfg.n_params(),
            scfg.valid_rows,
            scfg.task_items
        ),
        &header,
    );

    let score = |name: String, label: String, model: &SlabModel| {
        let t0 = std::time::Instant::now();
        let ppl = crate::eval::native::perplexity(model, &corpus.valid, opts);
        let (per_task, avg) = crate::eval::native::zero_shot(model, &suites, opts);
        eprintln!(
            "[sweep] {name} {label}: ppl {ppl:.3} acc {avg:.3} ({:.1}s, {} packed linears)",
            t0.elapsed().as_secs_f64(),
            model.packed_linear_count()
        );
        let mut row = vec![name, label, Table::metric(ppl)];
        row.extend(per_task.iter().map(|(_, a)| Table::pct(*a)));
        row.push(Table::pct(avg));
        row
    };

    // Dense reference row (the paper's 0% anchor).
    let dense_model = SlabModel::from_dense(params, 1);
    let row = score("Dense".into(), "0%".into(), &dense_model);
    table.push_row(row);
    drop(dense_model);

    for &cr in &scfg.ratios {
        for method in sweep_methods(scfg, cr) {
            let out = CompressJob::new(params, &corpus.calib, &method)
                .threads(scfg.threads)
                .run();
            match out {
                Ok(out) => {
                    // Packed serving for SLaB, dense reconstruction for
                    // the baselines; `threads = 1` because eval's
                    // parallelism lives in the row fan-out, not the
                    // model's kernel pool.
                    let model = out
                        .serving_model(params, 1)
                        .map_err(|e| anyhow::anyhow!("{e}"))?;
                    let row = score(method.name(), method.sparsity_label(), &model);
                    table.push_row(row);
                }
                Err(PipelineError::Method(e)) => {
                    eprintln!("[sweep] {} at {cr}: infeasible ({e})", method.name());
                    let mut row =
                        vec![method.name(), method.sparsity_label(), "infeasible".into()];
                    row.extend(vec!["-".to_string(); ALL_TASKS.len() + 1]);
                    table.push_row(row);
                }
                Err(e) => return Err(e.into()),
            }
        }

        // The two refined SLaB variants, same ratio, same global
        // parameter budget (the allocator conserves Σ keep exactly).
        let slab = Method::Slab(SlabConfig {
            cr,
            iters: scfg.iters,
            ..Default::default()
        });
        let rc = RefineConfig::with_rounds(scfg.refine_rounds);
        for (name, alloc) in [("SLaB+refine", false), ("SLaB+alloc", true)] {
            let mut job = CompressJob::new(params, &corpus.calib, &slab)
                .threads(scfg.threads)
                .refine(rc);
            if alloc {
                job = job.budget(BudgetConfig::default());
            }
            match job.run() {
                Ok(out) => {
                    let model = out
                        .serving_model(params, 1)
                        .map_err(|e| anyhow::anyhow!("{e}"))?;
                    let row = score(name.to_string(), slab.sparsity_label(), &model);
                    table.push_row(row);
                }
                Err(PipelineError::Method(e)) => {
                    eprintln!("[sweep] {name} at {cr}: infeasible ({e})");
                    let mut row = vec![name.to_string(), slab.sparsity_label(), "infeasible".into()];
                    row.extend(vec!["-".to_string(); ALL_TASKS.len() + 1]);
                    table.push_row(row);
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
    Ok(table)
}

/// Fig. 3: mean ‖W − Ŵ‖_F vs rank of W_L at CR 50% (weight-level,
/// no model eval — matches the paper's metric).
pub fn fig3(lab: &Lab, model: &str, max_rank: usize) -> anyhow::Result<Table> {
    let dense = lab.dense_params(model, lab.default_steps(model))?;
    let corpus = lab.corpus(model);
    let mut table = Table::new(
        "Fig. 3 — mean Frobenius error vs rank of W_L (CR 50%)",
        &["rank", "mean ‖W−Ŵ‖_F"],
    );
    for r in 0..=max_rank {
        let m = Method::Slab(SlabConfig {
            rank: r,
            iters: 8,
            ..Default::default()
        });
        let compressed = compress_model(&lab.rt, &dense, &corpus.calib, &m, Engine::Native)?;
        eprintln!("[fig3] rank {r}: frob {:.4}", compressed.report.mean_frob);
        table.push_row(vec![
            r.to_string(),
            format!("{:.4}", compressed.report.mean_frob),
        ]);
    }
    Ok(table)
}
