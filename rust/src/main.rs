//! `slab` — the leader binary: train, compress, evaluate, serve, and
//! regenerate every table/figure of the paper.
//!
//! ```text
//! slab train   --model base --steps 350
//! slab compress --model base --method slab --cr 0.5 [--pattern 2:4 | --semi]
//!              [--engine artifact]
//!              [--capture native|artifact] [--threads N] [--stream out.slabckpt]
//!              [--refine [--refine-rounds N]] [--budget alloc|uniform]
//!              # --refine: joint weighted re-fit after Algorithm 1;
//!              # --budget alloc: water-filled per-layer keep budgets
//! slab eval    --model base [--ckpt runs/base_slab.slabckpt]
//! slab eval    --engine native [--model small --ckpt runs/small.slabckpt]
//!              [--method slab --cr 0.5] [--threads 0]                   # artifact-free
//! slab sweep   [--model small|base|large] [--ratios 0.5,0.6] [--threads 0]
//!              [--items 8] [--rows 16] [--refine-rounds 2]
//!              [--csv runs/sweep.csv]                                   # artifact-free
//! slab table1  --models small,base,large [--groups "US (50%)"]
//! slab table2 | table3 | fig1 | fig3
//! slab serve   --model base --requests 64
//! slab serve   --http 127.0.0.1:8080 [--model small] [--ckpt runs/small.slabckpt]
//!              [--packed runs/small_slab.packed] [--batch 8] [--queue-cap 64]
//!              [--seq-cap N] [--deadline-ms 0] [--kv-page 8] [--page-budget 0]
//!              [--no-prefix-share] [--max-conns 256] [--keep-alive 64]
//!              [--http-workers 8]                                            # artifact-free
//!              [--speculate] [--draft-len 4] [--draft-rank R]  # lossless speculative decode
//! ```
//!
//! `slab --sweep` / `slab --eval` (no subcommand) are shorthands for
//! the two artifact-free paths — they need no `make artifacts`, no
//! checkpoint, and no Python toolchain anywhere.
//!
//! `--fast-kernels` (any subcommand; or `SLAB_KERNELS=fast`) opts the
//! batch-1 decode path into the tolerance-gated unrolled kernels
//! instead of the bit-exact scalar-order ones — see DESIGN.md §7 for
//! the parity policy.

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

use slab::baselines::{Method, SparseGptConfig};
use slab::coordinator::{
    load_packed_checkpoint, Backend, BudgetConfig, CaptureEngine, CompressJob, Engine, HttpConfig,
    HttpServer, Request, SchedulerConfig, Server, ServerConfig,
};
use slab::eval::{perplexity, zero_shot};
use slab::experiments::{self, Lab, SweepConfig};
use slab::model::{Params, SlabModel};
use slab::report::Table;
use slab::runtime::ModelCfg;
use slab::slab::{refine_table, RefineConfig, SlabConfig, Structure};
use slab::sparse::{PATTERN_2_4, PATTERN_4_8};
use slab::util::cli::Args;
use std::path::PathBuf;
use std::time::Duration;

fn main() {
    let args = match Args::from_env(true) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn lab(args: &Args) -> anyhow::Result<Lab> {
    let artifacts = PathBuf::from(args.get_str("artifacts", "artifacts"));
    let runs = PathBuf::from(args.get_str("runs", "runs"));
    let mut lab = Lab::new(&artifacts, &runs)?;
    lab.task_items = args.get_usize("items", 40)?;
    Ok(lab)
}

fn parse_method(args: &Args) -> anyhow::Result<Method> {
    let cr = args.get_f64("cr", 0.5)?;
    // --semi is shorthand for --pattern 2:4 — the hardware
    // semi-structured mode the wanda/sparsegpt baselines assume; the
    // dedicated 2:4 kernel (`NmPacked::row_dot_24`) serves its output.
    let pattern = match (args.get("pattern"), args.has_flag("semi")) {
        (Some("2:4"), _) | (None, true) => Some(PATTERN_2_4),
        (Some("4:8"), false) => Some(PATTERN_4_8),
        (Some("4:8"), true) => anyhow::bail!("--semi means 2:4; use --pattern 4:8 alone"),
        (None, false) => None,
        (Some(p), _) => anyhow::bail!("unknown pattern {p} (2:4 | 4:8)"),
    };
    let structure = match pattern {
        Some(p) => Structure::SemiStructured(p),
        None => Structure::Unstructured,
    };
    Ok(match args.get_str("method", "slab").as_str() {
        "slab" => Method::Slab(SlabConfig {
            cr,
            structure,
            iters: args.get_usize("iters", 20)?,
            ..Default::default()
        }),
        "wanda" => Method::Wanda {
            sparsity: cr,
            pattern,
        },
        "sparsegpt" => Method::SparseGpt {
            sparsity: cr,
            pattern,
            cfg: SparseGptConfig::default(),
        },
        "magnitude" => Method::Magnitude {
            sparsity: cr,
            pattern,
        },
        "dense" => Method::Dense,
        m => anyhow::bail!("unknown method {m}"),
    })
}

/// Native (manifest-free) shapes of the three evaluation configs —
/// mirrors `python/compile/model.py::CONFIGS` plus aot.py's
/// `prompt_len = max_seq // 2`, so the artifact-free paths score the
/// same checkpoints `slab train` writes (`Params::load` matches by
/// config name and per-param shapes).
fn native_model_cfg(name: &str) -> Option<ModelCfg> {
    Some(match name {
        "small" => ModelCfg::llama("small", 512, 64, 2, 4, 176, 64, 32),
        "base" => ModelCfg::llama("base", 512, 128, 4, 4, 344, 96, 48),
        "large" => ModelCfg::llama("large", 1024, 256, 6, 8, 688, 96, 48),
        _ => return None,
    })
}

/// Build the artifact-free sweep/eval configuration from CLI options
/// (defaults: `SweepConfig::quick`). `--model` accepts the built-in
/// `sweep` toy shape or `small|base|large`; anything else is an error
/// rather than a silently substituted model.
fn sweep_config(args: &Args) -> anyhow::Result<SweepConfig> {
    let mut scfg = SweepConfig::quick(args.get_u64("seed", 42)?);
    match args.get_str("model", "sweep").as_str() {
        "sweep" => {}
        name => {
            scfg.model = native_model_cfg(name).ok_or_else(|| {
                anyhow::anyhow!("unknown model '{name}' (sweep | small | base | large)")
            })?;
        }
    }
    if let Some(r) = args.get("ratios") {
        scfg.ratios = r
            .split(',')
            .map(|s| s.trim().parse::<f64>())
            .collect::<Result<Vec<f64>, _>>()
            .map_err(|_| anyhow::anyhow!("--ratios: expected comma-separated floats"))?;
    }
    scfg.valid_rows = args.get_usize("rows", scfg.valid_rows)?;
    scfg.calib_rows = args.get_usize("calib-rows", scfg.calib_rows)?;
    scfg.task_items = args.get_usize("items", scfg.task_items)?;
    scfg.threads = args.get_usize("threads", scfg.threads)?;
    scfg.eval_batch = args.get_usize("batch", scfg.eval_batch)?;
    scfg.iters = args.get_usize("iters", scfg.iters)?;
    scfg.refine_rounds = args.get_usize("refine-rounds", scfg.refine_rounds)?;
    Ok(scfg)
}

/// Sweep-shaped params: a checkpoint if given, else deterministic init.
fn sweep_params(args: &Args, scfg: &SweepConfig) -> anyhow::Result<Params> {
    Ok(match args.get("ckpt") {
        Some(p) => Params::load(&scfg.model, &PathBuf::from(p))?,
        None => Params::init(&scfg.model, scfg.seed ^ 0x1417),
    })
}

/// `slab sweep` / `slab --sweep`: the paper-style comparison matrix
/// (SLaB vs the four baselines × ratios, perplexity + zero-shot),
/// computed entirely on the native engine — no artifacts anywhere.
fn run_sweep(args: &Args) -> anyhow::Result<()> {
    let out_md = PathBuf::from(args.get_str("out", "runs/results.md"));
    let scfg = sweep_config(args)?;
    let params = sweep_params(args, &scfg)?;
    let t = experiments::sweep(&scfg, &params)?;
    t.print();
    t.append_to(&out_md)?;
    if let Some(p) = args.get("csv") {
        t.save_csv(&PathBuf::from(p))?;
        println!("wrote {p}");
    }
    println!("appended to {}", out_md.display());
    Ok(())
}

/// `slab serve --http <addr>`: the artifact-free HTTP front-end — a
/// native [`SlabModel`] behind the continuous-batching scheduler
/// behind `coordinator::http` (DESIGN.md §12). Streams tokens over
/// SSE-style chunked responses, cancels via `DELETE
/// /v1/sessions/{id}`, and reports live `ServeStats` on `/metrics`.
/// Serves until the process is killed.
fn run_http_serve(args: &Args, addr: &str) -> anyhow::Result<()> {
    let model_name = args.get_str("model", "small");
    let cfg = native_model_cfg(&model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{model_name}' (small | base | large)"))?;
    let params = match args.get("ckpt") {
        Some(p) => Params::load(&cfg, &PathBuf::from(p))?,
        None => Params::init(&cfg, args.get_u64("seed", 42)?),
    };
    let threads = args.get_usize("threads", 0)?;
    // --packed: serve the compression pipeline's packed checkpoint
    // straight through the packed engine (no dense Ŵ anywhere);
    // without it the dense params serve as-is.
    let model = match args.get("packed") {
        Some(p) => {
            let packed = load_packed_checkpoint(&PathBuf::from(p))
                .map_err(|e| anyhow::anyhow!("load packed checkpoint {p}: {e}"))?;
            let model = SlabModel::from_packed(&params, &packed, threads);
            println!(
                "serving packed checkpoint {p}: {} packed linears, {:.2} MiB resident",
                model.packed_linear_count(),
                model.weights_nbytes() as f64 / (1 << 20) as f64
            );
            model
        }
        None => SlabModel::from_dense(&params, threads),
    };
    let queue_cap = args.get_usize("queue-cap", 64)?;
    let scfg = ServerConfig {
        queue_cap,
        sched: SchedulerConfig {
            max_batch: args.get_usize("batch", 8)?,
            max_seq_len: args.get_usize("seq-cap", 0)?,
            queue_cap,
            deadline: Duration::from_millis(args.get_u64("deadline-ms", 0)?),
            // Paged KV (DESIGN.md §13): --kv-page 0 falls back to the
            // contiguous pool; --page-budget 0 is worst-case-safe.
            kv_page: args.get_usize("kv-page", 8)?,
            page_budget: args.get_usize("page-budget", 0)?,
            prefix_sharing: !args.has_flag("no-prefix-share"),
            // Self-speculative decoding (DESIGN.md §14): draft through
            // the sparse+low-rank view, verify with the full model —
            // lossless, so it's purely a throughput knob.
            speculate: args.has_flag("speculate"),
            draft_len: args.get_usize("draft-len", 4)?,
            draft_rank: args.get("draft-rank").map(|r| r.parse()).transpose()?,
        },
        ..Default::default()
    };
    let server = Server::start_with(Backend::NativeBatched(Box::new(model)), scfg);
    // Front-end knobs (DESIGN.md §15): --max-conns caps open
    // connections, --keep-alive is the per-connection request budget
    // (0 = Connection: close on every response), --http-workers sizes
    // the pool driving the blocking session API.
    let hcfg = HttpConfig {
        max_conns: args.get_usize("max-conns", 256)?,
        keep_alive_requests: args.get_usize("keep-alive", 64)?,
        workers: args.get_usize("http-workers", 8)?,
        ..Default::default()
    };
    let http = HttpServer::bind_with(addr, server, hcfg)
        .map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
    println!("listening on http://{}", http.addr());
    println!("  POST   /v1/generate       {{\"prompt\": [5,6,7], \"max_new\": 16, \"stream\": true, \"deadline_ms\": 500}}");
    println!("  DELETE /v1/sessions/{{id}}  cancel a live stream");
    println!("  GET    /healthz | /metrics");
    http.serve_forever();
    Ok(())
}

/// `slab eval --engine native` / `slab --eval`: artifact-free
/// single-model evaluation, optionally compressing first.
fn run_native_eval(args: &Args) -> anyhow::Result<()> {
    let scfg = sweep_config(args)?;
    let params = sweep_params(args, &scfg)?;
    let method = match args.get("method") {
        Some(_) => Some(parse_method(args)?),
        None => None,
    };
    let t = experiments::eval_native_table(&scfg, &params, method.as_ref())?;
    t.print();
    Ok(())
}

fn run(args: &Args) -> anyhow::Result<()> {
    if args.has_flag("fast-kernels") {
        // Latch before any kernel runs; tolerance-gated fast variants
        // replace the exact kernels on the batch-1 decode path
        // (DESIGN.md §7 documents the parity policy).
        if !slab::util::kernel::set_kernel_mode(slab::util::kernel::KernelMode::Fast) {
            eprintln!("warning: kernel mode already latched; --fast-kernels ignored");
        }
    }
    let out_md = PathBuf::from(args.get_str("out", "runs/results.md"));
    match args.command.as_deref() {
        Some("train") => {
            let lab = lab(args)?;
            let model = args.get_str("model", "base");
            let steps = args.get_usize("steps", lab.default_steps(&model))?;
            // Force retrain if requested.
            if args.has_flag("force") {
                let _ = std::fs::remove_file(lab.runs_dir.join(format!("{model}.slabckpt")));
            }
            let p = lab.dense_params(&model, steps)?;
            println!(
                "trained '{model}' ({} params) → {}",
                p.cfg.n_params(),
                lab.runs_dir.join(format!("{model}.slabckpt")).display()
            );
        }
        Some("compress") => {
            let lab = lab(args)?;
            let model = args.get_str("model", "base");
            let method = parse_method(args)?;
            let engine = match args.get_str("engine", "native").as_str() {
                "artifact" => Engine::Artifact,
                _ => Engine::Native,
            };
            let dense = lab.dense_params(&model, lab.default_steps(&model))?;
            let corpus = lab.corpus(&model);
            // Staged job: --capture native runs the calibration forward
            // without the embed/block_capture artifacts; --threads N
            // fans the decompose stage out (bit-identical to serial);
            // --stream writes packed layers per block.
            let capture = match args.get_str("capture", "artifact").as_str() {
                "native" => CaptureEngine::Native,
                _ => CaptureEngine::Artifact(&lab.rt),
            };
            let mut job = CompressJob::new(&dense, &corpus.calib, &method)
                .capture(capture)
                .engine(engine)
                .threads(args.get_usize("threads", 1)?);
            if let Some(p) = args.get("stream") {
                job = job.stream_to(PathBuf::from(p));
            }
            // --refine: joint activation-weighted re-fit after each
            // linear's one-shot decomposition; --budget alloc replaces
            // the uniform Eq.-10 keep fraction with the water-filled
            // per-layer plan (both SLaB + native engine only).
            if args.has_flag("refine") {
                job = job.refine(RefineConfig::with_rounds(args.get_usize("refine-rounds", 3)?));
            }
            match args.get_str("budget", "uniform").as_str() {
                "alloc" => job = job.budget(BudgetConfig::default()),
                "uniform" => {}
                b => anyhow::bail!("unknown --budget {b} (alloc | uniform)"),
            }
            let c = job.run()?;
            if let Some(plan) = &c.report.budget {
                plan.to_table().print();
            }
            if !c.report.refine.is_empty() {
                refine_table(&c.report.refine).print();
            }
            let out = lab
                .runs_dir
                .join(format!("{model}_{}.slabckpt", method.name().to_lowercase()));
            let params = c
                .params
                .ok_or_else(|| anyhow::anyhow!("compress job dropped its dense params"))?;
            params.save(&out)?;
            println!(
                "{} compressed '{model}' in {:.1}s — mean ‖W−Ŵ‖_F {:.4}, peak ≈{:.1} MiB → {}",
                method.name(),
                c.report.wall_secs,
                c.report.mean_frob,
                c.report.peak_bytes as f64 / (1 << 20) as f64,
                out.display()
            );
        }
        Some("eval") if args.get_str("engine", "artifact") == "native" => {
            run_native_eval(args)?;
        }
        Some("sweep") => {
            run_sweep(args)?;
        }
        Some("eval") => {
            let lab = lab(args)?;
            let model = args.get_str("model", "base");
            let cfg = lab
                .rt
                .manifest
                .config(&model)
                .ok_or_else(|| anyhow::anyhow!("unknown config"))?
                .clone();
            let params = match args.get("ckpt") {
                Some(p) => Params::load(&cfg, &PathBuf::from(p))?,
                None => lab.dense_params(&model, lab.default_steps(&model))?,
            };
            let corpus = lab.corpus(&model);
            let ppl = perplexity(&lab.rt, &params, &corpus.valid)?;
            let suites = lab.suites();
            let (per_task, avg) = zero_shot(&lab.rt, &params, &suites)?;
            let mut t = Table::new(
                &format!("Evaluation — {model}"),
                &["metric", "value"],
            );
            t.push_row(vec!["perplexity".into(), Table::metric(ppl)]);
            for (task, acc) in per_task {
                t.push_row(vec![task.name().into(), Table::pct(acc)]);
            }
            t.push_row(vec!["avg acc".into(), Table::pct(avg)]);
            t.print();
        }
        Some("table1") => {
            let lab = lab(args)?;
            let models = args.get_list("models", &["small", "base", "large"]);
            let groups = args.get_list("groups", &[]);
            let t = experiments::table1(&lab, &models, &groups)?;
            t.print();
            t.append_to(&out_md)?;
        }
        Some("table2") => {
            let lab = lab(args)?;
            let model = args.get_str("model", "base");
            let (a, b) = experiments::table2(&lab, &model)?;
            a.print();
            b.print();
            a.append_to(&out_md)?;
            b.append_to(&out_md)?;
        }
        Some("table3") => {
            let lab = lab(args)?;
            let model = args.get_str("model", "base");
            let t = experiments::table3(&lab, &model)?;
            t.print();
            t.append_to(&out_md)?;
        }
        Some("fig1") => {
            let lab = lab(args)?;
            let model = args.get_str("model", "base");
            let ranks: Vec<usize> = args
                .get_list("ranks", &["0", "1", "4", "16", "32"])
                .iter()
                .map(|s| s.parse().unwrap_or(0))
                .collect();
            let t = experiments::fig1(&lab, &model, &ranks)?;
            t.print();
            t.append_to(&out_md)?;
        }
        Some("fig3") => {
            let lab = lab(args)?;
            let model = args.get_str("model", "base");
            let max_rank = args.get_usize("max-rank", 6)?;
            let t = experiments::fig3(&lab, &model, max_rank)?;
            t.print();
            t.append_to(&out_md)?;
        }
        Some("serve") if args.get("http").is_some() => {
            // Artifact-free HTTP front-end over the native engine.
            let addr = args.get("http").unwrap_or_default().to_string();
            run_http_serve(args, &addr)?;
        }
        Some("serve") => {
            // No Lab here: xla_extension 0.5.1 cannot host two PJRT
            // clients in one process, and the Server's router thread
            // owns the only one. The checkpoint must already exist.
            let model = args.get_str("model", "base");
            let n_req = args.get_usize("requests", 32)?;
            let artifacts = PathBuf::from(args.get_str("artifacts", "artifacts"));
            let runs = PathBuf::from(args.get_str("runs", "runs"));
            let manifest = slab::runtime::Manifest::load(&artifacts)?;
            let cfg = manifest
                .config(&model)
                .ok_or_else(|| anyhow::anyhow!("unknown config {model}"))?
                .clone();
            let ckpt = match args.get("ckpt") {
                Some(p) => PathBuf::from(p),
                None => runs.join(format!("{model}.slabckpt")),
            };
            anyhow::ensure!(
                ckpt.exists(),
                "checkpoint {} missing — run `slab train --model {model}` first",
                ckpt.display()
            );
            let dense = Params::load(&cfg, &ckpt)?;
            let serve_batch = manifest.serve_batch;
            let server = Server::start(artifacts, dense, ServerConfig::default());
            let g = slab::data::Grammar::standard();
            let g = &g;
            let mut rng = slab::util::rng::Pcg64::seed_from_u64(9);
            let mut latencies = Vec::new();
            let sessions: Vec<_> = (0..n_req)
                .map(|_| {
                    server.submit(Request {
                        prompt: g.sample_sentence(&mut rng),
                        max_new: 16,
                        deadline: None,
                    })
                })
                .collect();
            for session in sessions {
                latencies.push(session.collect().latency_ms);
            }
            let stats = server.shutdown().map_err(|e| anyhow::anyhow!("{e}"))?;
            latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
            println!(
                "served {} requests in {} batches: {:.1} tok/s, ttft {:.0} ms, p50 {:.0} ms, p95 {:.0} ms, occupancy {:.2}",
                stats.requests,
                stats.batches,
                stats.tokens_per_sec(),
                stats.mean_ttft_ms(),
                latencies[latencies.len() / 2],
                latencies[latencies.len() * 95 / 100],
                stats.occupancy(serve_batch),
            );
        }
        // `slab --sweep` / `slab --eval`: the artifact-free quickstart
        // paths, reachable without remembering a subcommand.
        None if args.has_flag("sweep") => run_sweep(args)?,
        None if args.has_flag("eval") => run_native_eval(args)?,
        _ => {
            println!(
                "slab — Sparse-Lowrank-Binary decomposition for efficient LLMs\n\n\
                 commands: train | compress | eval | sweep | table1 | table2 | table3 | fig1 | fig3 | serve\n\
                 common options: --artifacts <dir> --runs <dir> --model <small|base|large> --items <n>\n\
                 artifact-free: `slab --sweep` (SLaB-vs-baselines table),\n\
                 `slab eval --engine native`, and `slab serve --http <addr>`\n\
                 (streaming JSON/SSE server) need no artifacts at all;\n\
                 everything else wants `make artifacts` first — see README.md"
            );
        }
    }
    Ok(())
}
