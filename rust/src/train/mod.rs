//! Training driver — the rust loop around the AOT `train_step_{cfg}`
//! artifact (fwd + bwd + AdamW fused in one XLA computation).
//!
//! No pretrained checkpoints exist offline, so the dense models the
//! paper prunes are produced here: rust owns the data order, step
//! loop, logging, and checkpointing; all math is inside the artifact.
//! State (params, m, v) round-trips as literals — outputs of step t
//! feed step t+1 without host-side decoding.

use crate::data::TokenSet;
use crate::model::Params;
use crate::runtime::client::RuntimeError;
use crate::runtime::{lit_i32, lit_scalar_i32, Runtime};
use crate::util::rng::Pcg64;

#[derive(Debug, Clone)]
pub struct TrainReport {
    /// (step, loss) at every logging point.
    pub loss_curve: Vec<(usize, f32)>,
    pub final_loss: f32,
    pub steps: usize,
    pub wall_secs: f64,
    pub tokens_per_sec: f64,
}

/// Train from `init` for `steps` steps over `corpus`; returns trained
/// params + the loss curve (recorded in EXPERIMENTS.md by the caller).
pub fn train(
    rt: &Runtime,
    init: &Params,
    corpus: &TokenSet,
    steps: usize,
    seed: u64,
    log_every: usize,
) -> Result<(Params, TrainReport), RuntimeError> {
    let cfg = init.cfg.clone();
    let name = format!("train_step_{}", cfg.name);
    let bsz = rt.manifest.train_batch;
    let width = cfg.max_seq + 1;
    assert_eq!(corpus.seq_len + 1, width, "corpus width vs model seq");

    let n = cfg.param_names.len();
    // State as literals: params ++ m ++ v.
    let mut state: Vec<xla::Literal> = init.to_literals();
    let zeros = Params::zeros_like(&cfg).to_literals();
    state.extend(zeros.iter().map(clone_lit));
    state.extend(zeros.iter().map(clone_lit));

    let mut rng = Pcg64::seed_from_u64(seed ^ 0x7ea1);
    let mut order: Vec<usize> = (0..corpus.rows).collect();
    rng.shuffle(&mut order);
    let mut cursor = 0usize;

    let mut loss_curve = Vec::new();
    let t0 = std::time::Instant::now();
    let mut last_loss = f32::NAN;
    for step in 0..steps {
        // Assemble the batch (reshuffle on wrap).
        let mut flat = Vec::with_capacity(bsz * width);
        for _ in 0..bsz {
            if cursor >= order.len() {
                rng.shuffle(&mut order);
                cursor = 0;
            }
            flat.extend_from_slice(corpus.row(order[cursor]));
            cursor += 1;
        }
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(3 * n + 2);
        inputs.append(&mut state);
        inputs.push(lit_scalar_i32(step as i32));
        inputs.push(lit_i32(&flat, &[bsz, width]));

        let mut out = rt.execute(&name, &inputs)?;
        // out = [loss, params.., m.., v..]
        let loss = out[0].get_first_element::<f32>().map_err(|e| {
            RuntimeError::Xla(format!("loss readback: {e}"))
        })?;
        last_loss = loss;
        state = out.split_off(1);
        debug_assert_eq!(state.len(), 3 * n);

        if step % log_every == 0 || step + 1 == steps {
            loss_curve.push((step, loss));
            eprintln!("[train {}] step {step:>5} loss {loss:.4}", cfg.name);
        }
        if !loss.is_finite() {
            return Err(RuntimeError::Xla(format!(
                "training diverged at step {step} (loss {loss})"
            )));
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let params_lits: Vec<xla::Literal> = state.drain(..n).collect();
    let trained = Params::from_literals(&cfg, &params_lits);
    let report = TrainReport {
        loss_curve,
        final_loss: last_loss,
        steps,
        wall_secs: wall,
        tokens_per_sec: (steps * bsz * cfg.max_seq) as f64 / wall.max(1e-9),
    };
    Ok((trained, report))
}

/// The xla crate's Literal is not Clone; round-trip through raw bytes.
fn clone_lit(l: &xla::Literal) -> xla::Literal {
    let v = l.to_vec::<f32>().expect("clone_lit f32");
    let shape = l.array_shape().expect("clone_lit shape");
    let dims: Vec<i64> = shape.dims().to_vec();
    xla::Literal::vec1(&v).reshape(&dims).expect("clone_lit reshape")
}
