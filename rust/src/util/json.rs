//! Minimal JSON substrate (parser + serializer).
//!
//! The vendored crate set has no `serde`/`serde_json`, so the artifact
//! manifest, run configs, and experiment reports use this module. It
//! implements the full JSON grammar (RFC 8259) with a recursive-descent
//! parser; numbers are held as `f64` plus an `i64` fast path.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` so serialization is
/// deterministic (stable key order) — important for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------------
    // Accessors
    // ------------------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() <= 9_007_199_254_740_992.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if missing or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index; `Json::Null` out of range.
    pub fn at(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ------------------------------------------------------------------
    // Constructors
    // ------------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    pub fn from_usize(n: usize) -> Json {
        Json::Num(n as f64)
    }

    /// Insert into an object (panics if self is not an object).
    pub fn set(&mut self, key: &str, val: Json) {
        match self {
            Json::Obj(o) => {
                o.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    // ------------------------------------------------------------------
    // Serialization (compact form via `Display`/`to_string`)
    // ------------------------------------------------------------------

    /// Pretty-print with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    // ------------------------------------------------------------------
    // Parsing
    // ------------------------------------------------------------------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Compact (no-whitespace) serialization; `value.to_string()` comes
/// via the blanket `ToString`. Use [`Json::to_pretty`] for the
/// indented form.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        f.write_str(&out)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; emit null like serde_json's lossy mode.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let combined =
                                0x10000 + (((cp - 0xD800) << 10) | (lo - 0xDC00));
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        out.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b).ok_or_else(|| self.err("bad utf-8"))?;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("bad utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self.peek().is_some_and(|b| b.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(b: u8) -> Option<usize> {
    match b {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

// ----------------------------------------------------------------------
// Lazy path-scanning reader
// ----------------------------------------------------------------------

/// Maximum container nesting the lazy scanner accepts. The tree
/// parser above recurses and would exhaust the thread stack on an
/// adversarial `[[[[…` body; the wire path must not, so the scanner
/// is iterative with an explicit depth cap.
pub const LAZY_MAX_DEPTH: usize = 64;

/// Lazy path-scanning JSON reader: one allocation-free *skip-scan*
/// validates well-formedness up front, then [`path`](LazyJson::path)
/// re-scans to a key path and returns the raw value slice without
/// ever building a tree. For request bodies where only a few fields
/// are read (`/v1/generate` reads four), this skips the
/// `BTreeMap`/`String`/`Vec` churn of [`Json::parse`] entirely; the
/// prompt array additionally gets a digits-to-`i64` fast path
/// ([`RawJson::int_array`]).
///
/// Contracts that differ from the tree parser, by design:
/// * duplicate keys: **first** occurrence wins (scan order); the tree
///   parser's `BTreeMap` keeps the last;
/// * `\uXXXX` escapes are hex-validated when skipped, but surrogate
///   pairing is only checked when a string is actually *extracted*.
pub struct LazyJson<'a> {
    src: &'a str,
}

/// A raw value slice from an already-validated document, returned by
/// [`LazyJson::path`]. Conversions re-scan the (small) slice.
#[derive(Clone, Copy, Debug)]
pub struct RawJson<'a> {
    src: &'a str,
}

struct Skip<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Skip<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &[u8]) -> Result<(), JsonError> {
        if self.bytes[self.pos..].starts_with(word) {
            self.pos += word.len();
            Ok(())
        } else {
            Err(self.err("invalid literal"))
        }
    }

    /// Skip one complete string, returning the raw inner slice
    /// (between the quotes, escapes unresolved).
    fn skip_string(&mut self) -> Result<&'a [u8], JsonError> {
        self.expect(b'"')?;
        let start = self.pos;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    let inner = &self.bytes[start..self.pos];
                    self.pos += 1;
                    return Ok(inner);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'u') => {
                            self.pos += 1;
                            for _ in 0..4 {
                                if !self.peek().is_some_and(|b| b.is_ascii_hexdigit()) {
                                    return Err(self.err("bad \\u escape"));
                                }
                                self.pos += 1;
                            }
                        }
                        Some(b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't') => {
                            self.pos += 1;
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                }
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(_) => self.pos += 1,
            }
        }
    }

    fn skip_number(&mut self) -> Result<(), JsonError> {
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut digits = 0usize;
        while self.peek().is_some_and(|b| b.is_ascii_digit()) {
            self.pos += 1;
            digits += 1;
        }
        if digits == 0 {
            return Err(self.err("expected digits"));
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            let mut frac = 0usize;
            while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                self.pos += 1;
                frac += 1;
            }
            if frac == 0 {
                return Err(self.err("expected fraction digits"));
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            let mut exp = 0usize;
            while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                self.pos += 1;
                exp += 1;
            }
            if exp == 0 {
                return Err(self.err("expected exponent digits"));
            }
        }
        Ok(())
    }

    /// Consume exactly one complete JSON value. Iterative — an
    /// explicit container stack capped at [`LAZY_MAX_DEPTH`] — so
    /// adversarial nesting cannot overflow the thread stack.
    fn skip_value(&mut self) -> Result<(), JsonError> {
        let mut stack: Vec<u8> = Vec::new();
        'value: loop {
            self.skip_ws();
            match self.peek() {
                Some(b'{') => {
                    self.pos += 1;
                    self.skip_ws();
                    if self.peek() == Some(b'}') {
                        self.pos += 1; // empty object: a finished value
                    } else {
                        if stack.len() >= LAZY_MAX_DEPTH {
                            return Err(self.err("nesting too deep"));
                        }
                        stack.push(b'{');
                        self.skip_string()?;
                        self.skip_ws();
                        self.expect(b':')?;
                        continue 'value;
                    }
                }
                Some(b'[') => {
                    self.pos += 1;
                    self.skip_ws();
                    if self.peek() == Some(b']') {
                        self.pos += 1;
                    } else {
                        if stack.len() >= LAZY_MAX_DEPTH {
                            return Err(self.err("nesting too deep"));
                        }
                        stack.push(b'[');
                        continue 'value;
                    }
                }
                Some(b'"') => {
                    self.skip_string()?;
                }
                Some(b't') => self.literal(b"true")?,
                Some(b'f') => self.literal(b"false")?,
                Some(b'n') => self.literal(b"null")?,
                Some(b) if b == b'-' || b.is_ascii_digit() => self.skip_number()?,
                _ => return Err(self.err("expected a JSON value")),
            }
            // One value finished; unwind enclosing containers.
            loop {
                let Some(&top) = stack.last() else {
                    return Ok(());
                };
                self.skip_ws();
                match (top, self.peek()) {
                    (b'[', Some(b',')) => {
                        self.pos += 1;
                        continue 'value;
                    }
                    (b'[', Some(b']')) => {
                        self.pos += 1;
                        stack.pop();
                    }
                    (b'{', Some(b',')) => {
                        self.pos += 1;
                        self.skip_ws();
                        self.skip_string()?;
                        self.skip_ws();
                        self.expect(b':')?;
                        continue 'value;
                    }
                    (b'{', Some(b'}')) => {
                        self.pos += 1;
                        stack.pop();
                    }
                    (b'[', _) => return Err(self.err("expected ',' or ']'")),
                    _ => return Err(self.err("expected ',' or '}'")),
                }
            }
        }
    }
}

fn key_matches(raw: &[u8], want: &str) -> bool {
    if !raw.contains(&b'\\') {
        return raw == want.as_bytes();
    }
    // Escaped key (rare): decode through the tree parser's string
    // reader for exact escape semantics.
    let Ok(raw_str) = std::str::from_utf8(raw) else {
        return false;
    };
    let quoted = format!("\"{raw_str}\"");
    let mut p = Parser {
        bytes: quoted.as_bytes(),
        pos: 0,
    };
    match p.string() {
        Ok(s) => s == want,
        Err(_) => false,
    }
}

impl<'a> LazyJson<'a> {
    /// Validate `src` as a single JSON document without building a
    /// tree. Rejects trailing garbage and nesting beyond
    /// [`LAZY_MAX_DEPTH`].
    pub fn parse(src: &'a str) -> Result<LazyJson<'a>, JsonError> {
        let mut s = Skip {
            bytes: src.as_bytes(),
            pos: 0,
        };
        s.skip_ws();
        s.skip_value()?;
        s.skip_ws();
        if s.pos != s.bytes.len() {
            return Err(s.err("trailing characters"));
        }
        Ok(LazyJson { src })
    }

    /// The whole document as a raw value.
    pub fn root(&self) -> RawJson<'a> {
        RawJson {
            src: self.src.trim(),
        }
    }

    /// Scan to `path` (object keys, outermost first) and return the
    /// raw value slice; `None` if a segment is missing or the value
    /// on the way is not an object.
    pub fn path(&self, path: &[&str]) -> Option<RawJson<'a>> {
        let mut s = Skip {
            bytes: self.src.as_bytes(),
            pos: 0,
        };
        for seg in path {
            s.skip_ws();
            if s.peek() != Some(b'{') {
                return None;
            }
            s.pos += 1;
            loop {
                s.skip_ws();
                if s.peek() == Some(b'}') {
                    return None; // key absent in this object
                }
                let key = s.skip_string().ok()?;
                s.skip_ws();
                s.expect(b':').ok()?;
                if key_matches(key, seg) {
                    break;
                }
                s.skip_value().ok()?;
                s.skip_ws();
                match s.peek() {
                    Some(b',') => s.pos += 1,
                    _ => return None, // '}' closes without the key
                }
            }
        }
        s.skip_ws();
        let start = s.pos;
        s.skip_value().ok()?;
        Some(RawJson {
            src: &self.src[start..s.pos],
        })
    }
}

impl<'a> RawJson<'a> {
    /// The raw text of the value.
    pub fn text(&self) -> &'a str {
        self.src
    }

    pub fn is_null(&self) -> bool {
        self.src == "null"
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self.src {
            "true" => Some(true),
            "false" => Some(false),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        let b = *self.src.as_bytes().first()?;
        if b != b'-' && !b.is_ascii_digit() {
            return None; // not a number token ("inf"/"nan" never leak in)
        }
        self.src.parse::<f64>().ok()
    }

    /// Same integer contract as [`Json::as_i64`]: integral value with
    /// |n| ≤ 2^53 (`3e2` is 300, `1.5` is rejected).
    pub fn as_i64(&self) -> Option<i64> {
        if let Ok(v) = self.src.parse::<i64>() {
            return Some(v);
        }
        let n = self.as_f64()?;
        if n.fract() == 0.0 && n.abs() <= 9_007_199_254_740_992.0 {
            Some(n as i64)
        } else {
            None
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    /// Decode a string value (escapes resolved; allocates).
    pub fn as_string(&self) -> Option<String> {
        let mut p = Parser {
            bytes: self.src.as_bytes(),
            pos: 0,
        };
        if p.peek() != Some(b'"') {
            return None;
        }
        let s = p.string().ok()?;
        if p.pos == p.bytes.len() {
            Some(s)
        } else {
            None
        }
    }

    /// Fast path for `[1, 2, 3]`-style token arrays: one scan, digits
    /// straight to `i64` (non-plain-integer elements fall back to the
    /// [`Json::as_i64`] integral-float contract; anything else errors).
    pub fn int_array(&self) -> Result<Vec<i64>, JsonError> {
        let mut s = Skip {
            bytes: self.src.as_bytes(),
            pos: 0,
        };
        s.skip_ws();
        s.expect(b'[')?;
        let mut out = Vec::new();
        s.skip_ws();
        if s.peek() == Some(b']') {
            s.pos += 1;
            return Ok(out);
        }
        loop {
            s.skip_ws();
            let start = s.pos;
            s.skip_number()?;
            let tok = &self.src[start..s.pos];
            let v = match tok.parse::<i64>() {
                Ok(v) => v,
                Err(_) => {
                    let f: f64 = tok.parse().map_err(|_| JsonError {
                        msg: "invalid number".to_string(),
                        pos: start,
                    })?;
                    if f.fract() == 0.0 && f.abs() <= 9_007_199_254_740_992.0 {
                        f as i64
                    } else {
                        return Err(JsonError {
                            msg: format!("non-integer element '{tok}'"),
                            pos: start,
                        });
                    }
                }
            };
            out.push(v);
            s.skip_ws();
            match s.peek() {
                Some(b',') => s.pos += 1,
                Some(b']') => {
                    s.pos += 1;
                    break;
                }
                _ => return Err(s.err("expected ',' or ']'")),
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.5", "1e3"] {
            let v = Json::parse(src).unwrap();
            let re = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, re, "roundtrip {src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").at(2).get("b").as_str(), Some("x"));
        assert!(v.get("c").is_null());
        assert_eq!(v.get("a").at(0).as_i64(), Some(1));
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "line\nquote\" back\\ tab\t unicode:\u{263a} null\u{0}";
        let v = Json::Str(s.to_string());
        let parsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(parsed.as_str(), Some(s));
    }

    #[test]
    fn surrogate_pairs() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,", "\"abc", "tru", "1.2.3", "{\"a\" 1}", "[] []"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn object_builder_and_pretty() {
        let v = Json::obj(vec![
            ("name", Json::str("slab")),
            ("dims", Json::arr([Json::num(64.0), Json::num(128.0)])),
            ("ok", Json::Bool(true)),
        ]);
        let pretty = v.to_pretty();
        assert!(pretty.contains("\"name\": \"slab\""));
        let re = Json::parse(&pretty).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn numbers_precise() {
        let v = Json::parse("[9007199254740991, -2.5e-3, 0.125]").unwrap();
        assert_eq!(v.at(0).as_i64(), Some(9007199254740991));
        assert!((v.at(1).as_f64().unwrap() + 0.0025).abs() < 1e-12);
        assert_eq!(v.at(2).as_f64(), Some(0.125));
    }

    #[test]
    fn stable_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"m":3,"z":1}"#);
    }

    #[test]
    fn lazy_agrees_with_tree_parser_on_validity() {
        let corpus = [
            "null",
            "true",
            "-1.5e3",
            r#""x\nA""#,
            "[1,[2,{}],{\"a\":[]}]",
            r#"{"a": {"b": null}, "c": [true, false]}"#,
            "{",
            "[1,",
            "\"abc",
            "tru",
            "1.2.3",
            "{\"a\" 1}",
            "[] []",
            "[1 2]",
            "{\"a\":}",
            "-",
            "1e",
            "[,]",
        ];
        for src in corpus {
            let tree = Json::parse(src).is_ok();
            let lazy = LazyJson::parse(src).is_ok();
            assert_eq!(tree, lazy, "parsers disagree on {src:?}");
        }
    }

    #[test]
    fn lazy_depth_cap_rejects_nesting_bombs() {
        let ok = "[".repeat(LAZY_MAX_DEPTH) + &"]".repeat(LAZY_MAX_DEPTH);
        assert!(LazyJson::parse(&ok).is_ok());
        let bomb = "[".repeat(LAZY_MAX_DEPTH + 1) + &"]".repeat(LAZY_MAX_DEPTH + 1);
        assert!(LazyJson::parse(&bomb).is_err());
    }

    #[test]
    fn lazy_path_extraction() {
        let body = concat!(
            r#"{"prompt": [1, 2, 3], "#,
            r#""opts": {"max_new": 3e2, "stream": true}, "#,
            r#""deadline_ms": 1.5}"#
        );
        let lz = LazyJson::parse(body).unwrap();
        assert_eq!(
            lz.path(&["prompt"]).unwrap().int_array().unwrap(),
            vec![1, 2, 3]
        );
        assert_eq!(lz.path(&["opts", "max_new"]).unwrap().as_i64(), Some(300));
        assert_eq!(lz.path(&["opts", "stream"]).unwrap().as_bool(), Some(true));
        assert_eq!(lz.path(&["deadline_ms"]).unwrap().as_f64(), Some(1.5));
        assert!(lz.path(&["missing"]).is_none());
        assert!(lz.path(&["prompt", "nested"]).is_none());
        assert!(lz.path(&["opts", "max_new", "deep"]).is_none());
        assert_eq!(lz.root().text(), body);
    }

    #[test]
    fn lazy_int_array_contract() {
        let lz = LazyJson::parse(
            r#"{"p": [1, 2.0, -3], "bad": [1.5], "worse": ["x"], "empty": []}"#,
        )
        .unwrap();
        assert_eq!(
            lz.path(&["p"]).unwrap().int_array().unwrap(),
            vec![1, 2, -3]
        );
        assert!(lz.path(&["bad"]).unwrap().int_array().is_err());
        assert!(lz.path(&["worse"]).unwrap().int_array().is_err());
        assert!(lz.path(&["empty"]).unwrap().int_array().unwrap().is_empty());
    }

    #[test]
    fn lazy_escaped_and_duplicate_keys() {
        // "\u0070" is 'p': escaped keys still match exactly.
        let lz = LazyJson::parse(r#"{"\u0070rompt": 1}"#).unwrap();
        assert_eq!(lz.path(&["prompt"]).unwrap().as_i64(), Some(1));
        // First duplicate wins on the lazy path (documented divergence
        // from the BTreeMap tree parser, which keeps the last).
        let dup = LazyJson::parse(r#"{"a": 1, "a": 2}"#).unwrap();
        assert_eq!(dup.path(&["a"]).unwrap().as_i64(), Some(1));
        // String extraction resolves escapes.
        let s = LazyJson::parse(r#"{"m": "a\nb"}"#).unwrap();
        assert_eq!(s.path(&["m"]).unwrap().as_string().as_deref(), Some("a\nb"));
        // Non-number tokens never coerce.
        assert!(s.path(&["m"]).unwrap().as_f64().is_none());
        assert!(s.path(&["m"]).unwrap().as_bool().is_none());
        assert!(!s.path(&["m"]).unwrap().is_null());
    }
}
