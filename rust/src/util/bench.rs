//! Benchmark harness substrate (no `criterion` offline).
//!
//! Provides warmup + timed iterations, robust summary statistics
//! (mean, std, median, p95, min/max), throughput reporting, and a
//! simple text table so `cargo bench` output mirrors what the paper's
//! tables/figures need. All benches under `rust/benches/` use this.

use std::time::{Duration, Instant};

/// Summary statistics over per-iteration wall-clock samples.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    pub fn from_samples(mut ns: Vec<f64>) -> Stats {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var = ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |q: f64| -> f64 {
            let idx = ((n as f64 - 1.0) * q).round() as usize;
            ns[idx.min(n - 1)]
        };
        Stats {
            iters: n,
            mean_ns: mean,
            std_ns: var.sqrt(),
            min_ns: ns[0],
            p50_ns: pct(0.50),
            p95_ns: pct(0.95),
            max_ns: ns[n - 1],
        }
    }

    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }

    /// items/sec given `items` units of work per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / (self.mean_ns * 1e-9)
    }
}

/// Human-friendly duration formatting.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop adding iterations once this much time has been spent.
    pub time_budget: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        // This testbed has a single CPU core; keep budgets modest so a
        // full `cargo bench` sweep completes in minutes.
        BenchConfig {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 200,
            time_budget: Duration::from_secs(3),
        }
    }
}

/// A named group of benchmark results printed as a table at the end.
pub struct Bench {
    group: String,
    cfg: BenchConfig,
    rows: Vec<(String, Stats, Option<(f64, &'static str)>)>,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        let mut cfg = BenchConfig::default();
        // Honor SLAB_BENCH_FAST=1 for smoke runs in CI/tests.
        if std::env::var("SLAB_BENCH_FAST").as_deref() == Ok("1") {
            cfg = BenchConfig {
                warmup_iters: 1,
                min_iters: 2,
                max_iters: 5,
                time_budget: Duration::from_millis(300),
            };
        }
        Bench {
            group: group.to_string(),
            cfg,
            rows: Vec::new(),
        }
    }

    pub fn with_config(mut self, cfg: BenchConfig) -> Bench {
        self.cfg = cfg;
        self
    }

    /// Run a closure repeatedly and record stats. The closure should
    /// return something observable to keep the optimizer honest; the
    /// value is black-boxed here.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> Stats {
        for _ in 0..self.cfg.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.cfg.min_iters
            || (samples.len() < self.cfg.max_iters && start.elapsed() < self.cfg.time_budget)
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let stats = Stats::from_samples(samples);
        eprintln!(
            "  {:<44} {:>12}/iter  (p50 {:>10}, p95 {:>10}, n={})",
            name,
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p50_ns),
            fmt_ns(stats.p95_ns),
            stats.iters
        );
        self.rows.push((name.to_string(), stats.clone(), None));
        stats
    }

    /// Like [`run`], additionally reporting throughput in `unit`/s for
    /// `items` units of work per iteration.
    pub fn run_throughput<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        items: f64,
        unit: &'static str,
        f: F,
    ) -> Stats {
        let stats = self.run(name, f);
        let row = self.rows.last_mut().unwrap();
        row.2 = Some((items, unit));
        stats
    }

    /// Print the final table for the group.
    pub fn finish(self) {
        println!("\n== bench group: {} ==", self.group);
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>16}",
            "benchmark", "mean", "p50", "p95", "throughput"
        );
        for (name, s, tp) in &self.rows {
            let tps = match tp {
                Some((items, unit)) => {
                    let v = s.throughput(*items);
                    if v >= 1e9 {
                        format!("{:.2} G{unit}/s", v / 1e9)
                    } else if v >= 1e6 {
                        format!("{:.2} M{unit}/s", v / 1e6)
                    } else if v >= 1e3 {
                        format!("{:.2} k{unit}/s", v / 1e3)
                    } else {
                        format!("{v:.2} {unit}/s")
                    }
                }
                None => "-".to_string(),
            };
            println!(
                "{:<44} {:>12} {:>12} {:>12} {:>16}",
                name,
                fmt_ns(s.mean_ns),
                fmt_ns(s.p50_ns),
                fmt_ns(s.p95_ns),
                tps
            );
        }
        println!();
    }
}

/// Optimizer barrier — a stable `std::hint::black_box` stand-in that
/// works on the MSRV of this repo.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let s = Stats::from_samples((1..=100).map(|i| i as f64).collect());
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 100.0);
        assert!((s.mean_ns - 50.5).abs() < 1e-9);
        assert!((s.p50_ns - 50.0).abs() <= 1.0);
        assert!((s.p95_ns - 95.0).abs() <= 1.0);
    }

    #[test]
    fn throughput_math() {
        let s = Stats::from_samples(vec![1e9]); // 1 second/iter
        assert!((s.throughput(1000.0) - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn runner_collects_min_iters() {
        std::env::set_var("SLAB_BENCH_FAST", "1");
        let mut b = Bench::new("test");
        let s = b.run("noop", || 1 + 1);
        assert!(s.iters >= 2);
        b.finish();
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(2_500.0).contains("µs"));
        assert!(fmt_ns(2_500_000.0).contains("ms"));
        assert!(fmt_ns(2_500_000_000.0).ends_with("s"));
    }
}
