//! Shared substrates: RNG, JSON, CLI parsing, bench harness,
//! property-testing helpers, thread pool, and small misc utilities.
//!
//! These exist because the offline vendored crate set ships no `rand`,
//! `serde_json`, `clap`, `criterion`, `proptest`, or `tokio`; each
//! submodule documents which external crate it replaces.

pub mod bench;
pub mod cli;
pub mod evloop;
pub mod json;
pub mod kernel;
pub mod pool;
pub mod prop;
pub mod rng;

use std::time::Instant;

/// Simple scoped wall-clock timer for coarse pipeline logging.
pub struct ScopeTimer {
    label: String,
    start: Instant,
    quiet: bool,
}

impl ScopeTimer {
    pub fn new(label: &str) -> ScopeTimer {
        ScopeTimer {
            label: label.to_string(),
            start: Instant::now(),
            quiet: false,
        }
    }

    pub fn quiet(label: &str) -> ScopeTimer {
        ScopeTimer {
            label: label.to_string(),
            start: Instant::now(),
            quiet: true,
        }
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

impl Drop for ScopeTimer {
    fn drop(&mut self) {
        if !self.quiet {
            eprintln!("[time] {}: {:.1} ms", self.label, self.elapsed_ms());
        }
    }
}

/// Format a float with engineering-style significant digits, used by
/// report tables (`5.49`, `113.8`, `1.34e4` like the paper).
pub fn fmt_metric(v: f64) -> String {
    if !v.is_finite() {
        return format!("{v}");
    }
    let a = v.abs();
    if a >= 1e4 {
        format!("{v:.2e}")
    } else if a >= 100.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_metric_matches_paper_style() {
        assert_eq!(fmt_metric(5.493), "5.49");
        assert_eq!(fmt_metric(113.77), "113.8");
        assert_eq!(fmt_metric(13400.0), "1.34e4");
    }

    #[test]
    fn scope_timer_measures() {
        let t = ScopeTimer::quiet("x");
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }
}
