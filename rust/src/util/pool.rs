//! Thread-pool + job-queue substrate (no `tokio` offline).
//!
//! The coordinator uses this for (a) the layer-wise pruning pipeline's
//! worker jobs and (b) the serving router's request handling. It is a
//! classic fixed-size pool over `std::sync::mpsc` with:
//!
//! * `execute(job)` — fire-and-forget,
//! * `scope`-style `map` — run a batch of jobs and collect results in
//!   input order,
//! * graceful shutdown on drop (workers drain the queue first).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// `size = 0` picks the available parallelism (min 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = if size == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            size
        };
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("slab-worker-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx, workers, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .send(Msg::Run(Box::new(f)))
            .expect("pool receiver alive");
    }

    /// Run `f` over `inputs` on the pool; results return in input order.
    /// Panics in jobs are converted into an `Err` for that slot.
    pub fn map<T, R, F>(&self, inputs: Vec<T>, f: F) -> Vec<Result<R, String>>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = inputs.len();
        let f = Arc::new(f);
        let (rtx, rrx) = channel::<(usize, Result<R, String>)>();
        for (i, input) in inputs.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(input)))
                    .map_err(|e| panic_msg(e.as_ref()));
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<Result<R, String>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker result");
            slots[i] = Some(r);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

fn panic_msg(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "job panicked".to_string()
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Msg>>>) {
    loop {
        let msg = {
            let guard = rx.lock().expect("pool lock");
            guard.recv()
        };
        match msg {
            Ok(Msg::Run(job)) => {
                // Panics are caught by `map`'s wrapper when used there;
                // for raw `execute` jobs we swallow the panic so one bad
                // job does not take the worker down.
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
            }
            Ok(Msg::Shutdown) | Err(_) => break,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100).collect(), |i: usize| i * i);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(*r.as_ref().unwrap(), i * i);
        }
    }

    #[test]
    fn execute_runs_all_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..50 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn panic_in_map_is_isolated() {
        let pool = ThreadPool::new(2);
        let out = pool.map(vec![1usize, 2, 3], |i| {
            if i == 2 {
                panic!("boom {i}");
            }
            i
        });
        assert_eq!(*out[0].as_ref().unwrap(), 1);
        assert!(out[1].as_ref().unwrap_err().contains("boom"));
        assert_eq!(*out[2].as_ref().unwrap(), 3);
    }

    #[test]
    fn zero_size_uses_available_parallelism() {
        let pool = ThreadPool::new(0);
        assert!(pool.size() >= 1);
    }
}
