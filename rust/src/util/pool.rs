//! Thread-pool + job-queue substrate (no `tokio`/`rayon` offline).
//!
//! The coordinator uses this for (a) the layer-wise pruning pipeline's
//! worker jobs, (b) the serving router's request handling, and (c) the
//! parallel packed kernels (`Csr::spmm_bt_par`,
//! `BitMat::matmul_bt_par`, `SlabLayer::forward_fused`). It is a
//! classic fixed-size pool over `std::sync::mpsc` with:
//!
//! * `execute(job)` — fire-and-forget,
//! * `map` — run a batch of owned jobs and collect results in input
//!   order,
//! * `scoped` — run a batch of *borrowing* jobs (rayon-scope-shaped;
//!   the kernel fork-join primitive) and block until all complete,
//! * graceful shutdown on drop (workers drain the queue first).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// `size = 0` picks the available parallelism (min 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = if size == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            size
        };
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("slab-worker-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx, workers, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .send(Msg::Run(Box::new(f)))
            .expect("pool receiver alive");
    }

    /// Run `f` over `inputs` on the pool; results return in input order.
    /// Panics in jobs are converted into an `Err` for that slot.
    pub fn map<T, R, F>(&self, inputs: Vec<T>, f: F) -> Vec<Result<R, String>>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = inputs.len();
        let f = Arc::new(f);
        let (rtx, rrx) = channel::<(usize, Result<R, String>)>();
        for (i, input) in inputs.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(input)))
                    .map_err(|e| panic_msg(e.as_ref()));
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<Result<R, String>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker result");
            slots[i] = Some(r);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }

    /// Run a batch of *borrowing* jobs on the pool and block until every
    /// one has finished — the fork-join primitive behind the parallel
    /// kernels. Unlike [`map`](ThreadPool::map), jobs may capture
    /// references to the caller's stack (the weight matrix, the
    /// activation batch, disjoint `&mut` output chunks), which is what
    /// a row-chunked matmul needs.
    ///
    /// Panics (after all jobs settled) if any job panicked, so a kernel
    /// bug cannot silently yield a half-written output.
    ///
    /// Must not be called from inside a pool worker (a pool of size 1
    /// would deadlock on itself); the kernels only call it from
    /// coordinator/serving threads.
    pub fn scoped<'env, F>(&self, jobs: Vec<F>)
    where
        F: FnOnce() + Send + 'env,
    {
        let n = jobs.len();
        if n == 0 {
            return;
        }
        let latch = Arc::new((Mutex::new(n), Condvar::new()));
        let poisoned = Arc::new(AtomicBool::new(false));
        for job in jobs {
            let latch = Arc::clone(&latch);
            let poisoned = Arc::clone(&poisoned);
            // SAFETY: the transmute only erases the `'env` lifetime of
            // the boxed job. We block on the latch below until every
            // job has run (the decrement lives in a drop guard, so a
            // panicking job still releases its slot), hence no borrow
            // captured by `job` outlives this call.
            let job: Box<dyn FnOnce() + Send + 'env> = Box::new(job);
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
            self.execute(move || {
                struct Guard(Arc<(Mutex<usize>, Condvar)>);
                impl Drop for Guard {
                    fn drop(&mut self) {
                        let (left, cv) = &*self.0;
                        let mut left = left.lock().unwrap_or_else(|p| p.into_inner());
                        *left -= 1;
                        if *left == 0 {
                            cv.notify_all();
                        }
                    }
                }
                let _guard = Guard(latch);
                if std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)).is_err() {
                    poisoned.store(true, Ordering::SeqCst);
                }
            });
        }
        let (left, cv) = &*latch;
        let mut left = left.lock().unwrap_or_else(|p| p.into_inner());
        while *left > 0 {
            left = cv.wait(left).unwrap_or_else(|p| p.into_inner());
        }
        drop(left);
        if poisoned.load(Ordering::SeqCst) {
            panic!("scoped pool job panicked");
        }
    }

    /// Run `f` over `items` as *borrowing* jobs on the pool and return
    /// the results **in input order** — [`scoped`](ThreadPool::scoped)
    /// plus a deterministic reduction, which is exactly the shape the
    /// compression pipeline's decompose stage needs: fan the
    /// independent linears of a block out, collect their reports and
    /// packed layers in the canonical order so the parallel run is
    /// bit-identical to the serial one.
    ///
    /// Same caveat as `scoped`: must not be called from inside a pool
    /// worker (nested fork-join on one pool can deadlock), and a
    /// panicking job propagates after all jobs settle.
    pub fn scoped_map<'env, T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'env,
        R: Send + 'env,
        F: Fn(T) -> R + Send + Sync + 'env,
    {
        let n = items.len();
        let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        {
            let fref = &f;
            let jobs: Vec<_> = items
                .into_iter()
                .zip(slots.iter_mut())
                .map(|(item, slot)| move || *slot = Some(fref(item)))
                .collect();
            self.scoped(jobs);
        }
        // `scoped` has already panicked if any job did, so every slot
        // is filled here.
        slots.into_iter().map(|s| s.expect("scoped job filled its slot")).collect()
    }
}

/// A bounded slot arena with stable integer handles and a free list —
/// the allocation substrate for per-session serving state (the
/// [`KvCachePool`](crate::model::KvCachePool) of the continuous
/// batcher). Slots are reused in LIFO order; a handle stays valid
/// until [`remove`](SlotArena::remove), and the arena never grows past
/// its capacity, which is what gives the scheduler a hard session cap.
#[derive(Debug)]
pub struct SlotArena<T> {
    slots: Vec<Option<T>>,
    free: Vec<usize>,
    cap: usize,
}

impl<T> SlotArena<T> {
    /// Arena holding at most `cap` live values (`cap ≥ 1` enforced).
    pub fn with_capacity(cap: usize) -> SlotArena<T> {
        SlotArena {
            slots: Vec::new(),
            free: Vec::new(),
            cap: cap.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Live values currently in the arena.
    pub fn len(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_full(&self) -> bool {
        self.len() >= self.cap
    }

    /// Insert a value, returning its handle — `None` when the arena is
    /// at capacity (the caller's backpressure signal).
    pub fn insert(&mut self, v: T) -> Option<usize> {
        if self.is_full() {
            return None;
        }
        match self.free.pop() {
            Some(id) => {
                debug_assert!(self.slots[id].is_none());
                self.slots[id] = Some(v);
                Some(id)
            }
            None => {
                self.slots.push(Some(v));
                Some(self.slots.len() - 1)
            }
        }
    }

    /// Remove and return the value at `id` (`None` if the slot is
    /// already vacant or the handle is out of range).
    pub fn remove(&mut self, id: usize) -> Option<T> {
        let v = self.slots.get_mut(id)?.take()?;
        self.free.push(id);
        Some(v)
    }

    pub fn get(&self, id: usize) -> Option<&T> {
        self.slots.get(id)?.as_ref()
    }

    pub fn get_mut(&mut self, id: usize) -> Option<&mut T> {
        self.slots.get_mut(id)?.as_mut()
    }

    /// Iterate `(handle, &value)` over live slots in handle order.
    pub fn iter(&self) -> impl Iterator<Item = (usize, &T)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|v| (i, v)))
    }
}

/// Refcounted page allocator — the block-paged KV storage substrate
/// ([`PagedKvPool`](crate::model::PagedKvPool), DESIGN.md §13). Built
/// on [`SlotArena`]: a page id is an arena handle whose value is the
/// page's reference count, so the free list, LIFO reuse, stable
/// handles, and the hard capacity are exactly the session-slot
/// machinery the scheduler already trusts.
///
/// [`alloc`](PageArena::alloc) hands out a page at refcount 1;
/// [`retain`](PageArena::retain) adds a sharer (copy-on-write prefix
/// sharing); [`release`](PageArena::release) drops one reference and
/// returns the page to the free list *exactly* when the count hits
/// zero — the no-double-free / no-leak contract the allocator fuzz
/// (`model::tests`) pins against a reference model. Releasing or
/// retaining a free page is a double-free-class bug and panics.
#[derive(Debug)]
pub struct PageArena {
    refs: SlotArena<u32>,
}

impl PageArena {
    /// Allocator over at most `n_pages` live pages (`≥ 1` enforced).
    pub fn with_capacity(n_pages: usize) -> PageArena {
        PageArena {
            refs: SlotArena::with_capacity(n_pages),
        }
    }

    /// Hard page budget.
    pub fn capacity(&self) -> usize {
        self.refs.capacity()
    }

    /// Pages currently allocated (refcount ≥ 1).
    pub fn allocated(&self) -> usize {
        self.refs.len()
    }

    /// Pages still allocatable.
    pub fn free_pages(&self) -> usize {
        self.capacity() - self.allocated()
    }

    /// Allocate a page at refcount 1 — `None` when the budget is
    /// exhausted (the caller's admit/evict signal).
    pub fn alloc(&mut self) -> Option<usize> {
        self.refs.insert(1)
    }

    /// Add one reference to a live page (a new sharer of a prefilled
    /// prefix). Panics on a free page.
    pub fn retain(&mut self, page: usize) {
        let rc = self.refs.get_mut(page).expect("retain of a free page");
        *rc += 1;
    }

    /// Drop one reference; frees the page (returns `true`) exactly
    /// when the last sharer releases. Panics on a free page — a
    /// double free must fail loudly, not corrupt the free list.
    pub fn release(&mut self, page: usize) -> bool {
        let rc = self.refs.get_mut(page).expect("release of a free page (double free)");
        *rc -= 1;
        if *rc == 0 {
            self.refs.remove(page);
            true
        } else {
            false
        }
    }

    /// Current reference count (`0` for a free page).
    pub fn refcount(&self, page: usize) -> u32 {
        self.refs.get(page).copied().unwrap_or(0)
    }
}

/// Split `0..n` into at most `parts` contiguous ranges of near-equal
/// length — the chunking scheme every row-parallel kernel uses. Empty
/// for `n == 0`; never yields an empty range.
pub fn chunk_ranges(n: usize, parts: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let chunk = n.div_ceil(parts);
    let mut out = Vec::with_capacity(parts);
    let mut r0 = 0;
    while r0 < n {
        let r1 = (r0 + chunk).min(n);
        out.push((r0, r1));
        r0 = r1;
    }
    out
}

fn panic_msg(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "job panicked".to_string()
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Msg>>>) {
    loop {
        let msg = {
            let guard = rx.lock().expect("pool lock");
            guard.recv()
        };
        match msg {
            Ok(Msg::Run(job)) => {
                // Panics are caught by `map`'s wrapper when used there;
                // for raw `execute` jobs we swallow the panic so one bad
                // job does not take the worker down.
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
            }
            Ok(Msg::Shutdown) | Err(_) => break,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100).collect(), |i: usize| i * i);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(*r.as_ref().unwrap(), i * i);
        }
    }

    #[test]
    fn execute_runs_all_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..50 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn panic_in_map_is_isolated() {
        let pool = ThreadPool::new(2);
        let out = pool.map(vec![1usize, 2, 3], |i| {
            if i == 2 {
                panic!("boom {i}");
            }
            i
        });
        assert_eq!(*out[0].as_ref().unwrap(), 1);
        assert!(out[1].as_ref().unwrap_err().contains("boom"));
        assert_eq!(*out[2].as_ref().unwrap(), 3);
    }

    #[test]
    fn zero_size_uses_available_parallelism() {
        let pool = ThreadPool::new(0);
        assert!(pool.size() >= 1);
    }

    #[test]
    fn scoped_jobs_borrow_disjoint_chunks() {
        // The exact shape the parallel kernels use: jobs write through
        // disjoint &mut chunks of a caller-owned buffer.
        for size in [1, 4] {
            let pool = ThreadPool::new(size);
            let mut out = vec![0usize; 64];
            let jobs: Vec<_> = out
                .chunks_mut(16)
                .enumerate()
                .map(|(c, chunk)| {
                    move || {
                        for (i, v) in chunk.iter_mut().enumerate() {
                            *v = c * 16 + i;
                        }
                    }
                })
                .collect();
            pool.scoped(jobs);
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v, i, "pool size {size}");
            }
        }
    }

    #[test]
    fn scoped_map_preserves_order_and_borrows() {
        // Jobs borrow caller-stack data and return owned results; the
        // reduction must be input-ordered regardless of completion
        // order (the decompose stage's determinism contract).
        let base = vec![10usize, 20, 30, 40, 50, 60, 70];
        for size in [1usize, 4] {
            let pool = ThreadPool::new(size);
            let out = pool.scoped_map((0..base.len()).collect(), |i| base[i] + i);
            let expect: Vec<usize> = base.iter().enumerate().map(|(i, &b)| b + i).collect();
            assert_eq!(out, expect, "pool size {size}");
        }
    }

    #[test]
    fn scoped_map_empty_is_noop() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.scoped_map(Vec::<usize>::new(), |i| i);
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic(expected = "scoped pool job panicked")]
    fn scoped_map_propagates_panics() {
        let pool = ThreadPool::new(2);
        let _ = pool.scoped_map(vec![0usize, 1, 2], |i| {
            if i == 1 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn scoped_empty_batch_is_noop() {
        let pool = ThreadPool::new(2);
        let jobs: Vec<fn()> = Vec::new();
        pool.scoped(jobs);
    }

    #[test]
    #[should_panic(expected = "scoped pool job panicked")]
    fn scoped_propagates_job_panics() {
        let pool = ThreadPool::new(2);
        let jobs: Vec<_> = (0..3)
            .map(|i| {
                move || {
                    if i == 1 {
                        panic!("boom");
                    }
                }
            })
            .collect();
        pool.scoped(jobs);
    }

    #[test]
    fn slot_arena_reuses_slots_and_respects_capacity() {
        let mut a: SlotArena<String> = SlotArena::with_capacity(2);
        assert_eq!(a.capacity(), 2);
        assert!(a.is_empty());
        let i0 = a.insert("a".to_string()).unwrap();
        let i1 = a.insert("b".to_string()).unwrap();
        assert_ne!(i0, i1);
        assert!(a.is_full());
        assert!(a.insert("c".to_string()).is_none(), "over capacity");
        assert_eq!(a.get(i0).unwrap(), "a");
        assert_eq!(a.remove(i0).unwrap(), "a");
        assert!(a.remove(i0).is_none(), "double remove is vacant");
        assert_eq!(a.len(), 1);
        // Freed slot is reused; the other handle stays valid.
        let i2 = a.insert("c".to_string()).unwrap();
        assert_eq!(i2, i0);
        assert_eq!(a.get(i1).unwrap(), "b");
        a.get_mut(i1).unwrap().push('!');
        let live: Vec<usize> = a.iter().map(|(i, _)| i).collect();
        assert_eq!(live.len(), 2);
        assert_eq!(a.get(i1).unwrap(), "b!");
        assert!(a.get(99).is_none());
        assert!(a.remove(99).is_none());
    }

    #[test]
    fn slot_arena_zero_capacity_clamps_to_one() {
        let mut a: SlotArena<u32> = SlotArena::with_capacity(0);
        assert_eq!(a.capacity(), 1);
        assert!(a.insert(7).is_some());
        assert!(a.insert(8).is_none());
    }

    #[test]
    fn page_arena_refcount_lifecycle() {
        let mut a = PageArena::with_capacity(3);
        assert_eq!(a.capacity(), 3);
        assert_eq!(a.free_pages(), 3);
        let p0 = a.alloc().unwrap();
        let p1 = a.alloc().unwrap();
        let p2 = a.alloc().unwrap();
        assert_eq!(a.allocated(), 3);
        assert_eq!(a.free_pages(), 0);
        assert!(a.alloc().is_none(), "over capacity");
        assert_eq!(a.refcount(p0), 1);
        // A second sharer keeps the page live through the first release.
        a.retain(p1);
        assert_eq!(a.refcount(p1), 2);
        assert!(!a.release(p1), "sharer remains");
        assert_eq!(a.refcount(p1), 1);
        assert!(a.release(p1), "last ref frees");
        assert_eq!(a.refcount(p1), 0, "free page reads as refcount 0");
        assert_eq!(a.free_pages(), 1);
        // Freed page id is recycled for the next alloc.
        let p3 = a.alloc().unwrap();
        assert_eq!(p3, p1);
        assert!(a.release(p0));
        assert!(a.release(p2));
        assert!(a.release(p3));
        assert_eq!(a.allocated(), 0);
        assert_eq!(a.free_pages(), a.capacity());
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn page_arena_release_of_free_page_panics() {
        let mut a = PageArena::with_capacity(2);
        let p = a.alloc().unwrap();
        assert!(a.release(p));
        a.release(p); // page already free: must fail loudly
    }

    #[test]
    #[should_panic(expected = "retain of a free page")]
    fn page_arena_retain_of_free_page_panics() {
        let mut a = PageArena::with_capacity(2);
        let p = a.alloc().unwrap();
        assert!(a.release(p));
        a.retain(p);
    }

    #[test]
    fn chunk_ranges_cover_without_overlap() {
        for (n, parts) in [(0usize, 4usize), (1, 4), (7, 3), (64, 4), (5, 16), (100, 1)] {
            let ranges = chunk_ranges(n, parts);
            if n == 0 {
                assert!(ranges.is_empty());
                continue;
            }
            assert!(ranges.len() <= parts.max(1));
            assert_eq!(ranges[0].0, 0);
            assert_eq!(ranges.last().unwrap().1, n);
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous n={n} parts={parts}");
            }
            for &(r0, r1) in &ranges {
                assert!(r0 < r1, "non-empty n={n} parts={parts}");
            }
        }
    }
}
