//! Kernel-variant selection: exact (bit-identical) vs fast
//! (reassociated SIMD) inner loops.
//!
//! The decode hot paths ship two implementations per kernel
//! (DESIGN.md §7):
//!
//! - **Exact** — accumulates each output element in the scalar
//!   reference order. Blocked/parallel/fused forms are bit-identical
//!   to the serial kernels, which is what every token-identity and
//!   conformance test in the repo compares with `==`.
//! - **Fast** — reassociates the accumulation into 4/8 independent
//!   chains so the compiler can vectorize across lanes and the CPU can
//!   overlap FP-add latency. Same math over the same terms, different
//!   summation tree: results differ from exact by a few ULPs and are
//!   gated by *tolerance* property tests (never `==`), with the bound
//!   derived from the term magnitudes (see `sparse::csr` /
//!   `binary` tests).
//!
//! Selection is process-global and write-once: serving defaults to
//! Exact; opt into Fast via `SLAB_KERNELS=fast` in the environment or
//! the `--fast-kernels` CLI flag (which must win over the env var, so
//! the CLI calls [`set_kernel_mode`] before any kernel runs). Bench
//! and test code bypasses the global by calling the `*_fast` entry
//! points directly.

use std::sync::OnceLock;

/// Which inner-kernel family the packed decode path runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelMode {
    /// Scalar accumulation order — bit-identical across blocked,
    /// parallel, and fused forms (the repo-wide determinism contract).
    #[default]
    Exact,
    /// Multi-accumulator / unrolled order — tolerance-gated, selected
    /// explicitly. Currently applied on the batch-1 fused decode path.
    Fast,
}

impl KernelMode {
    /// Read the mode from `SLAB_KERNELS` (`fast` ⇒ [`KernelMode::Fast`],
    /// anything else or unset ⇒ [`KernelMode::Exact`]).
    pub fn from_env() -> KernelMode {
        match std::env::var("SLAB_KERNELS").as_deref() {
            Ok("fast") | Ok("FAST") => KernelMode::Fast,
            _ => KernelMode::Exact,
        }
    }

    #[inline]
    pub fn is_fast(self) -> bool {
        self == KernelMode::Fast
    }
}

static MODE: OnceLock<KernelMode> = OnceLock::new();

/// The process-global kernel mode. First call latches the value
/// ([`set_kernel_mode`] if it ran first, else the environment).
#[inline]
pub fn kernel_mode() -> KernelMode {
    *MODE.get_or_init(KernelMode::from_env)
}

/// Pin the global mode before any kernel has read it (CLI startup).
/// Returns `false` if the mode was already latched — callers that
/// care (the CLI) can warn; tests call the explicit `*_fast` entry
/// points instead of mutating the global.
pub fn set_kernel_mode(mode: KernelMode) -> bool {
    MODE.set(mode).is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_exact() {
        assert_eq!(KernelMode::default(), KernelMode::Exact);
        assert!(!KernelMode::Exact.is_fast());
        assert!(KernelMode::Fast.is_fast());
    }

    #[test]
    fn global_latches_once() {
        // The getter latches on first read; a later set must report
        // "already latched" and leave the value stable. (Deliberately
        // never sets Fast here — the global is shared by the whole
        // test binary and the bit-identity suites assume Exact.)
        let first = kernel_mode();
        assert!(!set_kernel_mode(first) || kernel_mode() == first);
        assert_eq!(kernel_mode(), first);
    }
}
