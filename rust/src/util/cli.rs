//! Command-line argument parsing substrate (no `clap` offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`,
//! typed accessors with defaults, required options, and an
//! auto-generated `--help`. Kept deliberately small but featureful
//! enough for the `slab` binary and every example.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Declarative option spec for help generation + validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub required: bool,
    pub is_flag: bool,
}

/// A parsed command line: subcommand + options + positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub program: String,
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding program name is OK;
    /// pass `std::env::args()` and the first element is taken as program).
    pub fn parse_from<I: IntoIterator<Item = String>>(
        raw: I,
        has_subcommand: bool,
    ) -> Result<Args, CliError> {
        let mut it = raw.into_iter();
        let program = it.next().unwrap_or_else(|| "slab".into());
        let mut args = Args {
            program,
            ..Default::default()
        };
        let mut rest: Vec<String> = it.collect();
        if has_subcommand && !rest.is_empty() && !rest[0].starts_with('-') {
            args.command = Some(rest.remove(0));
        }
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminates option parsing.
                    args.positional.extend(rest[i + 1..].iter().cloned());
                    break;
                }
                if let Some(eq) = body.find('=') {
                    let (k, v) = body.split_at(eq);
                    args.opts.insert(k.to_string(), v[1..].to_string());
                } else if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                    args.opts.insert(body.to_string(), rest[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Parse the real process args.
    pub fn from_env(has_subcommand: bool) -> Result<Args, CliError> {
        Self::parse_from(std::env::args(), has_subcommand)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self
                .opts
                .get(name)
                .is_some_and(|v| v == "true" || v == "1")
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn require(&self, name: &str) -> Result<String, CliError> {
        self.get(name)
            .map(str::to_string)
            .ok_or_else(|| CliError(format!("missing required option --{name}")))
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected float, got '{v}'"))),
        }
    }

    pub fn get_f32(&self, name: &str, default: f32) -> Result<f32, CliError> {
        Ok(self.get_f64(name, default as f64)? as f32)
    }

    /// Comma-separated list of values.
    pub fn get_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        }
    }

    /// Validate against specs: unknown options rejected, required
    /// enforced. Returns formatted help on `--help`.
    pub fn validate(&self, specs: &[OptSpec]) -> Result<(), CliError> {
        for key in self.opts.keys().chain(self.flags.iter()) {
            if key == "help" {
                continue;
            }
            if !specs.iter().any(|s| s.name == key) {
                return Err(CliError(format!("unknown option --{key}")));
            }
        }
        for s in specs.iter().filter(|s| s.required) {
            if self.get(s.name).is_none() {
                return Err(CliError(format!("missing required option --{}", s.name)));
            }
        }
        Ok(())
    }

    pub fn wants_help(&self) -> bool {
        self.has_flag("help")
    }
}

/// Render a help string for a command.
pub fn render_help(program: &str, command: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut out = String::new();
    out.push_str(&format!("{program} {command} — {about}\n\nOptions:\n"));
    for s in specs {
        let mut line = format!("  --{}", s.name);
        if !s.is_flag {
            line.push_str(" <v>");
        }
        while line.len() < 28 {
            line.push(' ');
        }
        line.push_str(s.help);
        if let Some(d) = s.default {
            line.push_str(&format!(" [default: {d}]"));
        }
        if s.required {
            line.push_str(" (required)");
        }
        out.push_str(&line);
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(line: &str) -> Args {
        let raw: Vec<String> = std::iter::once("slab".to_string())
            .chain(line.split_whitespace().map(str::to_string))
            .collect();
        Args::parse_from(raw, true).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        // NOTE: a bare flag must not swallow a following positional, so
        // flags go after positionals or use --flag=true; here the
        // positional precedes the flag.
        let a = parse("compress --model base --cr 0.5 file.bin --verbose");
        assert_eq!(a.command.as_deref(), Some("compress"));
        assert_eq!(a.get("model"), Some("base"));
        assert_eq!(a.get_f64("cr", 0.0).unwrap(), 0.5);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["file.bin"]);
    }

    #[test]
    fn equals_syntax() {
        let a = parse("train --steps=300 --lr=3e-4");
        assert_eq!(a.get_usize("steps", 0).unwrap(), 300);
        assert!((a.get_f64("lr", 0.0).unwrap() - 3e-4).abs() < 1e-12);
    }

    #[test]
    fn flags_at_end_and_defaults() {
        let a = parse("eval --fast");
        assert!(a.has_flag("fast"));
        assert_eq!(a.get_usize("batch", 8).unwrap(), 8);
        assert_eq!(a.get_str("out", "runs"), "runs");
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse("run -- --not-an-option x");
        assert_eq!(a.positional, vec!["--not-an-option", "x"]);
    }

    #[test]
    fn list_option() {
        let a = parse("sweep --ranks 0,1,4,16");
        assert_eq!(a.get_list("ranks", &[]), vec!["0", "1", "4", "16"]);
    }

    #[test]
    fn validate_unknown_and_required() {
        let specs = [
            OptSpec {
                name: "model",
                help: "model preset",
                default: None,
                required: true,
                is_flag: false,
            },
            OptSpec {
                name: "fast",
                help: "quick mode",
                default: None,
                required: false,
                is_flag: true,
            },
        ];
        let ok = parse("x --model base --fast");
        assert!(ok.validate(&specs).is_ok());
        let missing = parse("x --fast");
        assert!(missing.validate(&specs).is_err());
        let unknown = parse("x --model base --bogus 1");
        assert!(unknown.validate(&specs).is_err());
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("x --n abc");
        assert!(a.get_usize("n", 1).is_err());
    }

    #[test]
    fn help_rendering() {
        let specs = [OptSpec {
            name: "cr",
            help: "compression ratio",
            default: Some("0.5"),
            required: false,
            is_flag: false,
        }];
        let h = render_help("slab", "compress", "prune a model", &specs);
        assert!(h.contains("--cr"));
        assert!(h.contains("[default: 0.5]"));
    }
}
