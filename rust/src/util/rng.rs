//! Deterministic pseudo-random number generation substrate.
//!
//! The vendored crate set has no `rand` implementation (only the
//! `rand_core` traits), so SLaB carries its own PRNG stack:
//!
//! * [`SplitMix64`] — seed expander (Steele et al., 2014).
//! * [`Pcg64`] — PCG-XSL-RR 128/64 (O'Neill, 2014), the workhorse
//!   generator. Passes BigCrush; 2^128 period; cheap to fork.
//! * Distribution helpers: uniform, normal (Box–Muller), Zipf
//!   (rejection-inversion), categorical, shuffles and subsampling.
//!
//! Everything in the repo that needs randomness takes an explicit
//! `&mut Pcg64` so every experiment is reproducible from a single seed
//! recorded in the run config.

/// SplitMix64: used to expand a 64-bit seed into PCG's 128-bit state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSL-RR 128/64. Deterministic, forkable, serializable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Build from a 64-bit seed (stream 0).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let i0 = sm.next_u64() as u128;
        let i1 = sm.next_u64() as u128;
        Self::from_state((s0 << 64) | s1, (i0 << 64) | i1)
    }

    /// Build from explicit 128-bit state + stream. The stream (`inc`)
    /// is forced odd per the PCG reference implementation.
    pub fn from_state(state: u128, stream: u128) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.step();
        rng.state = rng.state.wrapping_add(state);
        rng.step();
        rng
    }

    /// Fork an independent generator (distinct stream derived from the
    /// parent). Used to give each pipeline stage / worker its own
    /// deterministic stream.
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        let s = ((self.next_u64() as u128) << 64) | self.next_u64() as u128;
        let stream = s ^ ((tag as u128) << 17) ^ 0x5851_F42D_4C95_7F2D;
        Self::from_state(s.rotate_left(29), stream)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Core output function: XSL-RR.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) with 24 bits of precision.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased uniform integer in [0, bound) (Lemire's method).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let threshold = bound.wrapping_neg() % bound;
            while lo < threshold {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, bound).
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform i64 in [lo, hi).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (uses one cached value would add
    /// state; we draw the pair and discard the second — clarity over
    /// the last nanosecond, this is not on the request path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std, f32.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with N(0, std) values.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }

    /// Fill a slice with U[lo, hi).
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.range_f32(lo, hi);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below_usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Categorical draw from (unnormalized, non-negative) weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical: all-zero weights");
        let mut t = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Precomputed Zipf(s) sampler over ranks 1..=n, returned 0-based.
///
/// Uses the inverse-CDF table (n is small in our corpus lexicons, so a
/// table beats rejection-inversion in both simplicity and speed).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Self { cdf }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Draw a 0-based rank (0 = most frequent).
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference values for seed 1234567 from the public domain
        // SplitMix64 implementation.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn pcg_deterministic_and_stream_dependent() {
        let mut a = Pcg64::seed_from_u64(42);
        let mut b = Pcg64::seed_from_u64(42);
        let mut c = Pcg64::seed_from_u64(43);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Pcg64::seed_from_u64(7);
        let mut f1 = root.fork(1);
        let mut f2 = root.fork(2);
        let a: Vec<u64> = (0..16).map(|_| f1.next_u64()).collect();
        let b: Vec<u64> = (0..16).map(|_| f2.next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn uniform_unit_interval() {
        let mut rng = Pcg64::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = rng.next_f32();
            assert!((0.0..1.0).contains(&y));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::seed_from_u64(2);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let v = rng.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed_from_u64(3);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..n {
            let x = rng.normal();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed_from_u64(4);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::seed_from_u64(5);
        let idx = rng.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn zipf_rank_ordering() {
        let mut rng = Pcg64::seed_from_u64(6);
        let z = Zipf::new(100, 1.1);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // Rank 0 strictly more frequent than rank 10 more than rank 90.
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[90]);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Pcg64::seed_from_u64(8);
        let mut hits = [0usize; 3];
        for _ in 0..30_000 {
            hits[rng.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(hits[2] > hits[1] && hits[1] > hits[0]);
        let p2 = hits[2] as f64 / 30_000.0;
        assert!((p2 - 0.7).abs() < 0.03, "p2={p2}");
    }
}
