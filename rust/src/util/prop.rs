//! Mini property-testing substrate (no `proptest` offline).
//!
//! `check(name, cases, gen, prop)` draws `cases` random inputs from a
//! seeded generator and asserts the property on each. On failure it
//! attempts greedy shrinking via the input's [`Shrink`] impl and
//! reports the smallest failing case together with the seed so the
//! exact run is reproducible (`SLAB_PROP_SEED` overrides).
//!
//! This mirrors how proptest is used by the test-suite mandate:
//! randomized coverage of invariants with actionable minimal
//! counterexamples.

use crate::util::rng::Pcg64;

/// Types that can propose structurally smaller variants of themselves.
pub trait Shrink: Sized {
    /// Candidate shrinks, largest-step first. Default: none.
    fn shrinks(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Shrink for f32 {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
        }
        out
    }
}

impl Shrink for Vec<f32> {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.len() > 1 {
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[self.len() / 2..].to_vec());
        }
        if !self.is_empty() {
            let mut zeroed = self.clone();
            for v in zeroed.iter_mut() {
                *v = 0.0;
            }
            if &zeroed != self {
                out.push(zeroed);
            }
        }
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrinks(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrinks()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrinks().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run a property over `cases` random inputs. Panics with the minimal
/// failing input (after greedy shrinking) and the seed.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    T: Shrink + Clone + std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let seed = std::env::var("SLAB_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5eedu64 ^ 0x51ab_0000_0000_0000u64 ^ name.len() as u64);
    let mut rng = Pcg64::seed_from_u64(seed ^ hash_name(name));
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(input, msg, &mut prop);
            panic!(
                "property '{name}' failed (case {case}, seed {seed}):\n  {min_msg}\n  minimal input: {min_input:?}"
            );
        }
    }
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn shrink_loop<T, P>(mut cur: T, mut msg: String, prop: &mut P) -> (T, String)
where
    T: Shrink + Clone,
    P: FnMut(&T) -> Result<(), String>,
{
    // Greedy: repeatedly take the first shrink that still fails.
    // Bounded to avoid pathological loops.
    for _ in 0..200 {
        let mut advanced = false;
        for cand in cur.shrinks() {
            if let Err(m) = prop(&cand) {
                cur = cand;
                msg = m;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (cur, msg)
}

/// Convenience generators used across the test suite.
pub mod gens {
    use crate::util::rng::Pcg64;

    /// Vec of standard-normal f32s with length in [lo, hi].
    pub fn normal_vec(rng: &mut Pcg64, lo: usize, hi: usize) -> Vec<f32> {
        let n = lo + rng.below_usize(hi - lo + 1);
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    /// Matrix dims in [lo, hi] each.
    pub fn dims(rng: &mut Pcg64, lo: usize, hi: usize) -> (usize, usize) {
        (
            lo + rng.below_usize(hi - lo + 1),
            lo + rng.below_usize(hi - lo + 1),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "sum-commutes",
            100,
            |rng| gens::normal_vec(rng, 1, 32),
            |v| {
                let fwd: f32 = v.iter().sum();
                let rev: f32 = v.iter().rev().sum();
                if (fwd - rev).abs() <= 1e-3 * (1.0 + fwd.abs()) {
                    Ok(())
                } else {
                    Err(format!("fwd={fwd} rev={rev}"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_shrunk_input() {
        check(
            "always-fails",
            10,
            |rng| gens::normal_vec(rng, 4, 32),
            |v| {
                if v.len() < 2 {
                    Ok(())
                } else {
                    Err("too long".into())
                }
            },
        );
    }

    #[test]
    fn shrinking_reduces_usize() {
        let mut prop = |x: &usize| if *x < 3 { Ok(()) } else { Err("≥3".into()) };
        let (min, _) = shrink_loop(100usize, "≥3".into(), &mut prop);
        assert_eq!(min, 3);
    }

    #[test]
    fn deterministic_given_seed() {
        std::env::set_var("SLAB_PROP_SEED", "99");
        let mut first: Vec<Vec<f32>> = Vec::new();
        check(
            "capture",
            5,
            |rng| gens::normal_vec(rng, 1, 8),
            |v| {
                first.push(v.clone());
                Ok(())
            },
        );
        let mut second: Vec<Vec<f32>> = Vec::new();
        check(
            "capture",
            5,
            |rng| gens::normal_vec(rng, 1, 8),
            |v| {
                second.push(v.clone());
                Ok(())
            },
        );
        std::env::remove_var("SLAB_PROP_SEED");
        assert_eq!(first, second);
    }
}
