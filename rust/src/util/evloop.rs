//! Readiness polling over raw file descriptors — the event substrate
//! for the HTTP front-end (`coordinator::http`), standing in for what
//! `mio`/`tokio` would provide in a crates.io build.
//!
//! Two interchangeable backends behind one [`Poller`] API:
//!
//! * **epoll** (Linux, default): `epoll_create1` / `epoll_ctl` /
//!   `epoll_wait` through hand-declared FFI — O(ready) wakeups.
//! * **poll** (portable fallback, and [`Poller::new`]`(force_poll =
//!   true)` in tests): POSIX `poll(2)` over a rebuilt fd array —
//!   O(registered) per wait, which is fine at the front-end's
//!   connection limits and keeps the fallback exercised on Linux CI
//!   instead of rotting.
//!
//! Plus a self-pipe [`Waker`] so worker threads can interrupt a
//! blocked [`Poller::wait`], and `setsockopt` helpers the slow-client
//! tests use to shrink kernel socket buffers to deterministic sizes.
//!
//! Every `unsafe` block here is a single FFI call whose arguments are
//! fully owned by the caller (no retained pointers, no callbacks).
//! This module is intentionally *not* in the miri unsafe-audit filter:
//! foreign calls cannot run under the interpreter, so the audit
//! surface for it is this one file plus its loopback unit tests.

use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, TcpStream};
use std::os::raw::{c_int, c_void};
use std::os::unix::io::{FromRawFd, RawFd};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Interest bit: readiness to read (or peer hang-up).
pub const EV_READ: u8 = 0b01;
/// Interest bit: readiness to write.
pub const EV_WRITE: u8 = 0b10;

/// One readiness report from [`Poller::wait`].
#[derive(Clone, Copy, Debug)]
pub struct PollEvent {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    pub error: bool,
}

#[repr(C)]
#[derive(Clone, Copy)]
struct PollFd {
    fd: c_int,
    events: i16,
    revents: i16,
}

#[cfg(target_os = "linux")]
type Nfds = std::os::raw::c_ulong;
#[cfg(not(target_os = "linux"))]
type Nfds = std::os::raw::c_uint;

const POLLIN: i16 = 0x1;
const POLLOUT: i16 = 0x4;
const POLLERR: i16 = 0x8;
const POLLHUP: i16 = 0x10;
const POLLNVAL: i16 = 0x20;

#[cfg(target_os = "linux")]
const SOL_SOCKET: c_int = 1;
#[cfg(target_os = "linux")]
const SO_SNDBUF: c_int = 7;
#[cfg(target_os = "linux")]
const SO_RCVBUF: c_int = 8;
#[cfg(not(target_os = "linux"))]
const SOL_SOCKET: c_int = 0xffff;
#[cfg(not(target_os = "linux"))]
const SO_SNDBUF: c_int = 0x1001;
#[cfg(not(target_os = "linux"))]
const SO_RCVBUF: c_int = 0x1002;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: Nfds, timeout_ms: c_int) -> c_int;
    fn pipe(fds: *mut c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn close(fd: c_int) -> c_int;
    fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
    fn connect(fd: c_int, addr: *const c_void, len: u32) -> c_int;
    fn setsockopt(fd: c_int, level: c_int, name: c_int, value: *const c_void, len: u32) -> c_int;
}

#[cfg(target_os = "linux")]
mod ep {
    use super::c_int;

    /// Kernel ABI: `epoll_event` is packed on x86_64 only (the u64
    /// payload would otherwise pad to 16 bytes); other arches use
    /// natural alignment. Mirrors libc's `cfg_attr`.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    pub const EPOLL_CLOEXEC: c_int = 0o2000000;
    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;
    pub const EPOLLIN: u32 = 0x1;
    pub const EPOLLOUT: u32 = 0x4;
    pub const EPOLLERR: u32 = 0x8;
    pub const EPOLLHUP: u32 = 0x10;

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, ev: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            evs: *mut EpollEvent,
            max_events: c_int,
            timeout_ms: c_int,
        ) -> c_int;
    }
}

enum Backend {
    #[cfg(target_os = "linux")]
    Epoll { epfd: RawFd },
    /// token -> (fd, interest); the pollfd array is rebuilt per wait.
    Poll { fds: HashMap<u64, (RawFd, u8)> },
}

/// Level-triggered readiness poller. Register fds under caller-chosen
/// `u64` tokens; [`wait`](Poller::wait) reports which tokens are
/// ready. The caller keeps ownership of every fd (dropping a
/// registered fd without `deregister` is reported as `error` by the
/// poll backend and silently unregistered by epoll — the front-end
/// always deregisters first).
pub struct Poller {
    backend: Backend,
}

#[cfg(target_os = "linux")]
fn ep_ctl(epfd: RawFd, op: c_int, fd: RawFd, token: u64, interest: u8) -> io::Result<()> {
    let mut ev = ep::EpollEvent {
        events: interest_to_epoll(interest),
        data: token,
    };
    let rc = unsafe { ep::epoll_ctl(epfd, op, fd, &mut ev) };
    if rc < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(())
    }
}

#[cfg(target_os = "linux")]
fn interest_to_epoll(interest: u8) -> u32 {
    let mut e = 0u32;
    if interest & EV_READ != 0 {
        e |= ep::EPOLLIN;
    }
    if interest & EV_WRITE != 0 {
        e |= ep::EPOLLOUT;
    }
    e
}

impl Poller {
    /// `force_poll` selects the portable `poll(2)` backend even where
    /// epoll is available, so both code paths run on Linux CI.
    pub fn new(force_poll: bool) -> io::Result<Poller> {
        #[cfg(target_os = "linux")]
        if !force_poll {
            let epfd = unsafe { ep::epoll_create1(ep::EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            return Ok(Poller {
                backend: Backend::Epoll { epfd },
            });
        }
        #[cfg(not(target_os = "linux"))]
        let _ = force_poll;
        Ok(Poller {
            backend: Backend::Poll {
                fds: HashMap::new(),
            },
        })
    }

    pub fn register(&mut self, fd: RawFd, token: u64, interest: u8) -> io::Result<()> {
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll { epfd } => ep_ctl(*epfd, ep::EPOLL_CTL_ADD, fd, token, interest),
            Backend::Poll { fds } => {
                fds.insert(token, (fd, interest));
                Ok(())
            }
        }
    }

    pub fn modify(&mut self, fd: RawFd, token: u64, interest: u8) -> io::Result<()> {
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll { epfd } => ep_ctl(*epfd, ep::EPOLL_CTL_MOD, fd, token, interest),
            Backend::Poll { fds } => {
                fds.insert(token, (fd, interest));
                Ok(())
            }
        }
    }

    pub fn deregister(&mut self, fd: RawFd, token: u64) -> io::Result<()> {
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll { epfd } => ep_ctl(*epfd, ep::EPOLL_CTL_DEL, fd, 0, 0),
            Backend::Poll { fds } => {
                fds.remove(&token);
                Ok(())
            }
        }
    }

    /// Block up to `timeout` (`None` = indefinitely) and fill `out`
    /// (cleared first) with ready tokens. `EINTR` is retried.
    pub fn wait(&mut self, out: &mut Vec<PollEvent>, timeout: Option<Duration>) -> io::Result<()> {
        out.clear();
        let ms: c_int = match timeout {
            None => -1,
            Some(d) => d.as_millis().min(i32::MAX as u128) as c_int,
        };
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll { epfd } => {
                let mut evs = [ep::EpollEvent { events: 0, data: 0 }; 64];
                let n = loop {
                    let rc =
                        unsafe { ep::epoll_wait(*epfd, evs.as_mut_ptr(), evs.len() as c_int, ms) };
                    if rc >= 0 {
                        break rc as usize;
                    }
                    let err = io::Error::last_os_error();
                    if err.kind() != io::ErrorKind::Interrupted {
                        return Err(err);
                    }
                };
                for ev in evs.iter().take(n) {
                    // By-value copies: never take references into the
                    // (possibly packed) struct.
                    let events = ev.events;
                    let token = ev.data;
                    out.push(PollEvent {
                        token,
                        readable: events & (ep::EPOLLIN | ep::EPOLLHUP) != 0,
                        writable: events & ep::EPOLLOUT != 0,
                        error: events & (ep::EPOLLERR | ep::EPOLLHUP) != 0,
                    });
                }
                Ok(())
            }
            Backend::Poll { fds } => {
                let mut pfds: Vec<PollFd> = Vec::with_capacity(fds.len());
                let mut tokens: Vec<u64> = Vec::with_capacity(fds.len());
                for (&token, &(fd, interest)) in fds.iter() {
                    let mut events: i16 = 0;
                    if interest & EV_READ != 0 {
                        events |= POLLIN;
                    }
                    if interest & EV_WRITE != 0 {
                        events |= POLLOUT;
                    }
                    pfds.push(PollFd {
                        fd,
                        events,
                        revents: 0,
                    });
                    tokens.push(token);
                }
                let n = loop {
                    let rc = unsafe { poll(pfds.as_mut_ptr(), pfds.len() as Nfds, ms) };
                    if rc >= 0 {
                        break rc;
                    }
                    let err = io::Error::last_os_error();
                    if err.kind() != io::ErrorKind::Interrupted {
                        return Err(err);
                    }
                };
                if n == 0 {
                    return Ok(());
                }
                for (i, p) in pfds.iter().enumerate() {
                    if p.revents == 0 {
                        continue;
                    }
                    out.push(PollEvent {
                        token: tokens[i],
                        readable: p.revents & (POLLIN | POLLHUP) != 0,
                        writable: p.revents & POLLOUT != 0,
                        error: p.revents & (POLLERR | POLLHUP | POLLNVAL) != 0,
                    });
                }
                Ok(())
            }
        }
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        #[cfg(target_os = "linux")]
        if let Backend::Epoll { epfd } = self.backend {
            let _ = unsafe { close(epfd) };
        }
    }
}

struct WakerInner {
    write_fd: RawFd,
    armed: AtomicBool,
}

impl Drop for WakerInner {
    fn drop(&mut self) {
        let _ = unsafe { close(self.write_fd) };
    }
}

/// Self-pipe waker: worker threads call [`Waker::wake`] to interrupt
/// the event loop's [`Poller::wait`]. The `armed` flag coalesces
/// wakes so the pipe holds at most one unread byte — `wake` can never
/// block on a full pipe no matter how many messages are queued.
#[derive(Clone)]
pub struct Waker {
    inner: Arc<WakerInner>,
}

/// Loop-side end of the waker pipe. Register [`fd`](WakeReader::fd)
/// for `EV_READ`; on readiness call [`drain`](WakeReader::drain)
/// *before* draining the message queue, so a send racing the drain
/// still lands a fresh wake byte.
pub struct WakeReader {
    read_fd: RawFd,
    inner: Arc<WakerInner>,
}

/// Create a connected (waker, reader) pair over a fresh pipe.
pub fn waker() -> io::Result<(Waker, WakeReader)> {
    let mut fds = [0 as c_int; 2];
    let rc = unsafe { pipe(fds.as_mut_ptr()) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    let inner = Arc::new(WakerInner {
        write_fd: fds[1],
        armed: AtomicBool::new(false),
    });
    Ok((
        Waker {
            inner: inner.clone(),
        },
        WakeReader {
            read_fd: fds[0],
            inner,
        },
    ))
}

impl Waker {
    pub fn wake(&self) {
        // Only the first wake between two drains writes a byte; the
        // rest piggyback. Rust ignores SIGPIPE, so writing after the
        // reader closed is a plain EPIPE error we can drop.
        if !self.inner.armed.swap(true, Ordering::AcqRel) {
            let byte = [1u8];
            let _ = unsafe { write(self.inner.write_fd, byte.as_ptr() as *const c_void, 1) };
        }
    }
}

impl WakeReader {
    pub fn fd(&self) -> RawFd {
        self.read_fd
    }

    /// Drain the wake byte and re-arm. Call only after the fd polled
    /// readable (the pipe is blocking; level-triggered readiness
    /// guarantees the byte is still there).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        let _ = unsafe { read(self.read_fd, buf.as_mut_ptr() as *mut c_void, buf.len()) };
        self.inner.armed.store(false, Ordering::Release);
    }
}

impl Drop for WakeReader {
    fn drop(&mut self) {
        let _ = unsafe { close(self.read_fd) };
    }
}

fn set_buf_opt(fd: RawFd, opt: c_int, bytes: usize) -> io::Result<()> {
    let v: c_int = bytes.min(i32::MAX as usize) as c_int;
    let rc = unsafe {
        setsockopt(
            fd,
            SOL_SOCKET,
            opt,
            (&v as *const c_int) as *const c_void,
            std::mem::size_of::<c_int>() as u32,
        )
    };
    if rc < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(())
    }
}

/// Clamp a socket's kernel send buffer. The HTTP front-end applies
/// this to accepted sockets when `HttpConfig::sndbuf` is set, making
/// slow-client backpressure observable at small byte counts in tests
/// instead of being absorbed by ~200 KB of default kernel buffering.
pub fn set_sndbuf(fd: RawFd, bytes: usize) -> io::Result<()> {
    set_buf_opt(fd, SO_SNDBUF, bytes)
}

/// Clamp a socket's kernel receive buffer (post-creation).
pub fn set_rcvbuf(fd: RawFd, bytes: usize) -> io::Result<()> {
    set_buf_opt(fd, SO_RCVBUF, bytes)
}

/// Connect a TCP socket whose receive buffer is clamped *before* the
/// handshake — `SO_RCVBUF` set pre-connect caps the TCP window the
/// peer is ever offered, which post-connect shrinking cannot
/// retroactively do. Test-side lever for the slow-client
/// write-budget path (IPv4 only; that is all the loopback tests use).
#[cfg(target_os = "linux")]
pub fn connect_with_rcvbuf(addr: SocketAddr, bytes: usize) -> io::Result<TcpStream> {
    let SocketAddr::V4(v4) = addr else {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            "connect_with_rcvbuf supports ipv4 addresses only",
        ));
    };
    const AF_INET: c_int = 2;
    const SOCK_STREAM: c_int = 1;
    #[repr(C)]
    struct SockaddrIn {
        sin_family: u16,
        sin_port: u16,
        sin_addr: u32,
        sin_zero: [u8; 8],
    }
    let fd = unsafe { socket(AF_INET, SOCK_STREAM, 0) };
    if fd < 0 {
        return Err(io::Error::last_os_error());
    }
    if let Err(e) = set_buf_opt(fd, SO_RCVBUF, bytes) {
        let _ = unsafe { close(fd) };
        return Err(e);
    }
    let sa = SockaddrIn {
        sin_family: AF_INET as u16,
        sin_port: v4.port().to_be(),
        sin_addr: u32::from_ne_bytes(v4.ip().octets()),
        sin_zero: [0u8; 8],
    };
    let rc = unsafe {
        connect(
            fd,
            (&sa as *const SockaddrIn) as *const c_void,
            std::mem::size_of::<SockaddrIn>() as u32,
        )
    };
    if rc < 0 {
        let err = io::Error::last_os_error();
        let _ = unsafe { close(fd) };
        return Err(err);
    }
    // The fd is a connected, healthy TCP socket we exclusively own.
    Ok(unsafe { TcpStream::from_raw_fd(fd) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::time::Instant;

    fn pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = l.accept().unwrap();
        (a, b)
    }

    fn backend_reports_readiness(force_poll: bool) {
        let (mut a, b) = pair();
        b.set_nonblocking(true).unwrap();
        let mut p = Poller::new(force_poll).unwrap();
        p.register(b.as_raw_fd(), 7, EV_READ).unwrap();
        let mut evs = Vec::new();
        // Nothing to read yet: a short wait reports no readable token.
        p.wait(&mut evs, Some(Duration::from_millis(10))).unwrap();
        assert!(evs.iter().all(|e| e.token != 7 || !e.readable));
        a.write_all(b"x").unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            p.wait(&mut evs, Some(Duration::from_millis(50))).unwrap();
            if evs.iter().any(|e| e.token == 7 && e.readable) {
                break;
            }
            assert!(Instant::now() < deadline, "no readable event within 5s");
        }
        // Write interest on an empty send buffer reports writable.
        p.modify(b.as_raw_fd(), 7, EV_READ | EV_WRITE).unwrap();
        p.wait(&mut evs, Some(Duration::from_millis(100))).unwrap();
        assert!(evs.iter().any(|e| e.token == 7 && e.writable));
        p.deregister(b.as_raw_fd(), 7).unwrap();
        p.wait(&mut evs, Some(Duration::from_millis(10))).unwrap();
        assert!(evs.iter().all(|e| e.token != 7));
        drop(a);
        drop(b);
    }

    #[test]
    fn default_backend_reports_readiness() {
        backend_reports_readiness(false);
    }

    #[test]
    fn poll_fallback_backend_reports_readiness() {
        backend_reports_readiness(true);
    }

    fn waker_unblocks_wait(force_poll: bool) {
        let (w, r) = waker().unwrap();
        let mut p = Poller::new(force_poll).unwrap();
        p.register(r.fd(), 9, EV_READ).unwrap();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            // Coalescing: many wakes land at most one pipe byte.
            for _ in 0..100 {
                w.wake();
            }
        });
        let mut evs = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            p.wait(&mut evs, Some(Duration::from_millis(100))).unwrap();
            if evs.iter().any(|e| e.token == 9 && e.readable) {
                break;
            }
            assert!(Instant::now() < deadline, "waker never fired");
        }
        t.join().unwrap();
        r.drain();
        // Drained and re-armed: the pipe is quiet again.
        p.wait(&mut evs, Some(Duration::from_millis(10))).unwrap();
        assert!(evs.iter().all(|e| e.token != 9 || !e.readable));
    }

    #[test]
    fn waker_unblocks_default_backend() {
        waker_unblocks_wait(false);
    }

    #[test]
    fn waker_unblocks_poll_backend() {
        waker_unblocks_wait(true);
    }

    #[test]
    fn sndbuf_clamp_applies() {
        let (a, _b) = pair();
        set_sndbuf(a.as_raw_fd(), 4096).unwrap();
        set_rcvbuf(a.as_raw_fd(), 4096).unwrap();
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn connect_with_rcvbuf_talks_tcp() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = l.accept().unwrap();
            let mut buf = [0u8; 4];
            s.read_exact(&mut buf).unwrap();
            s.write_all(&buf).unwrap();
        });
        let mut s = connect_with_rcvbuf(addr, 4096).unwrap();
        s.write_all(b"ping").unwrap();
        let mut buf = [0u8; 4];
        s.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"ping");
        h.join().unwrap();
    }
}
