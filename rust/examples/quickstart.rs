//! Quickstart: decompose one weight matrix with SLaB and inspect what
//! you get, then compress a whole tiny model through the staged
//! pipeline (native capture → parallel decompose → streaming emit) —
//! no artifacts needed anywhere (pure native path).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

use slab::slab::{decompose, ActStats, SlabConfig, SlabLayer};
use slab::tensor::{matmul_bt, Mat};
use slab::util::rng::Pcg64;

fn main() {
    // A fake "linear layer": weight (256 out, 512 in) + calibration
    // activations (1024 samples).
    let mut rng = Pcg64::seed_from_u64(7);
    let w = Mat::randn(256, 512, 0.02, &mut rng);
    let x = Mat::randn(1024, 512, 1.0, &mut rng);
    let stats = ActStats::from_activations(&x);

    // Decompose at 50% compression (paper defaults: rank 1, 20 iters,
    // groups (1, Din), FP16 accounting).
    let cfg = SlabConfig::default();
    let d = decompose(&w, &stats, &cfg).expect("decompose");

    println!("SLaB quickstart — W (256x512) at CR {:.0}%", cfg.cr * 100.0);
    println!("  keep fraction (Eq.10): {:.4}", cfg.keep_fraction(256, 512).unwrap());
    println!("  non-zeros kept in W_S: {} / {}", d.kept, w.numel());
    println!("  Frobenius error per iteration: {:?}",
        d.frob_trace.iter().map(|v| format!("{v:.4}")).collect::<Vec<_>>());

    // The packed deployment format.
    let layer = SlabLayer::from_decomposition(&d);
    let dense_bytes = w.numel() * 4;
    println!("  deployed bytes: {} (dense f32: {}, ratio {:.2}x)",
        layer.nbytes_deploy(), dense_bytes,
        dense_bytes as f64 / layer.nbytes_deploy() as f64);

    // Compressed forward ≡ dense forward with the reconstruction.
    let xb = Mat::randn(4, 512, 1.0, &mut rng);
    let y_packed = layer.forward(&xb);
    let y_dense = matmul_bt(&xb, &layer.reconstruct());
    println!("  packed-vs-dense forward max |Δ|: {:.2e}",
        y_packed.sub(&y_dense).max_abs());

    // Compare against plain Wanda at the same CR.
    let wanda = slab::baselines::wanda_prune(&w, &stats, 0.5, None);
    println!("  ‖W−Ŵ‖_F: SLaB {:.4} vs Wanda {:.4}",
        w.frob_dist(&d.reconstruct()), wanda.frob_err);

    // ---- whole-model compression through the staged pipeline --------
    // Native calibration capture (no XLA artifacts), layer-parallel
    // decompose (bit-identical to serial), streaming emit: packed
    // layers hit disk as each block finishes, and nothing dense is
    // retained — the memory-lean configuration.
    use slab::baselines::Method;
    use slab::coordinator::{load_packed_checkpoint, CompressJob};
    use slab::data::TokenSet;
    use slab::model::{Params, SlabModel};
    use slab::runtime::ModelCfg;

    let mcfg = ModelCfg::llama("quickstart", 48, 32, 2, 4, 64, 24, 8);
    let params = Params::init(&mcfg, 11);
    let calib = TokenSet::synthetic(8, mcfg.max_seq, mcfg.vocab);
    let method = Method::Slab(SlabConfig { iters: 4, svd_iters: 8, ..Default::default() });
    let ckpt = std::env::temp_dir().join("slab-quickstart/packed.slabckpt");
    let out = CompressJob::new(&params, &calib, &method)
        .threads(0) // available parallelism
        .keep_dense(false)
        .keep_packed(false)
        .stream_to(ckpt.clone())
        .run()
        .expect("compress job");
    println!(
        "\nstaged pipeline: {} linears compressed in {:.2}s, peak ≈{:.2} MiB (streaming, no dense copy)",
        out.report.layers.len(),
        out.report.wall_secs,
        out.report.peak_bytes as f64 / (1 << 20) as f64
    );

    // Reload the streamed checkpoint and serve from it directly.
    let packed = load_packed_checkpoint(&ckpt).expect("reload packed checkpoint");
    let model = SlabModel::from_packed(&params, &packed, 0);
    let generated = model.generate_batch(&[vec![5, 6, 7]], 8);
    println!(
        "  reloaded {} packed linears ({:.2} MiB resident) and generated {:?}",
        model.packed_linear_count(),
        model.weights_nbytes() as f64 / (1 << 20) as f64,
        generated[0]
    );
}
