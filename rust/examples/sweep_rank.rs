//! Rank sweeps — regenerates Fig. 1 and Fig. 3 in one run.
//!
//! Fig. 1: naive sparse + rank-r low-rank at a joint 50% CR — the
//! strawman whose perplexity *worsens* with rank (the low-rank factors
//! eat the sparse budget).
//! Fig. 3: SLaB with rank-r `W_L` — the big Frobenius drop from rank 0
//! (Wanda) to rank 1, then diminishing returns, motivating the
//! paper's rank-1 choice.
//!
//! ```bash
//! make artifacts && cargo run --release --example sweep_rank -- [--model small]
//! ```

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

use slab::experiments::{self, Lab};
use slab::util::cli::Args;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false).map_err(|e| anyhow::anyhow!("{e}"))?;
    let model = args.get_str("model", "small");
    let artifacts = PathBuf::from(args.get_str("artifacts", "artifacts"));
    let runs = PathBuf::from(args.get_str("runs", "runs"));
    let mut lab = Lab::new(&artifacts, &runs)?;
    lab.task_items = args.get_usize("items", 30).unwrap_or(30);

    let ranks: Vec<usize> = args
        .get_list("ranks", &["0", "1", "4", "8", "16"])
        .iter()
        .filter_map(|s| s.parse().ok())
        .collect();

    let fig1 = experiments::fig1(&lab, &model, &ranks)?;
    fig1.print();
    fig1.append_to(&runs.join("results.md"))?;

    let max_rank = args.get_usize("max-rank", 4).unwrap_or(4);
    let fig3 = experiments::fig3(&lab, &model, max_rank)?;
    fig3.print();
    fig3.append_to(&runs.join("results.md"))?;
    Ok(())
}
