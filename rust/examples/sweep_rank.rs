//! Rank sweeps — regenerates Fig. 1 and Fig. 3 in one run.
//!
//! Fig. 1: naive sparse + rank-r low-rank at a joint 50% CR — the
//! strawman whose perplexity *worsens* with rank (the low-rank factors
//! eat the sparse budget).
//! Fig. 3: SLaB with rank-r `W_L` — the big Frobenius drop from rank 0
//! (Wanda) to rank 1, then diminishing returns, motivating the
//! paper's rank-1 choice.
//!
//! ```bash
//! make artifacts && cargo run --release --example sweep_rank -- [--model small]
//! ```
//!
//! `--refine` runs the artifact-free refinement demo instead: rank-r
//! decompositions of a synthetic layer, one-shot vs jointly refined
//! ([`slab::slab::refine`]) under the activation-weighted metric — no
//! artifacts, no Lab, finishes in seconds.
//!
//! ```bash
//! cargo run --release --example sweep_rank -- --refine [--rounds 3]
//! ```

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

use slab::experiments::{self, Lab};
use slab::report::Table;
use slab::slab::{decompose, refine, ActStats, RefineConfig, SlabConfig};
use slab::tensor::Mat;
use slab::util::cli::Args;
use slab::util::rng::Pcg64;
use std::path::PathBuf;

/// Artifact-free demo: decompose a synthetic 96×192 layer at several
/// ranks, then refine each decomposition — the table shows the
/// activation-weighted error one-shot vs refined at identical budgets.
fn refine_demo(args: &Args) -> anyhow::Result<()> {
    let rounds = args.get_usize("rounds", 3).unwrap_or(3);
    let mut rng = Pcg64::seed_from_u64(args.get_u64("seed", 7).unwrap_or(7));
    let (dout, din) = (96usize, 192usize);
    let w = Mat::randn(dout, din, 0.05, &mut rng);
    let x = Mat::randn(128, din, 1.0, &mut rng);
    let stats = ActStats::from_activations(&x);

    let mut t = Table::new(
        &format!("Refinement demo — {dout}x{din} layer, CR 50%, {rounds} rounds"),
        &["rank", "werr one-shot", "werr refined", "improv %", "rounds run"],
    );
    for rank in [0usize, 1, 2, 4] {
        let cfg = SlabConfig { rank, iters: 8, ..Default::default() };
        let d = decompose(&w, &stats, &cfg)?;
        let (_, rep) = refine(&w, &d, &stats, &cfg, &RefineConfig::with_rounds(rounds))?;
        t.push_row(vec![
            rank.to_string(),
            format!("{:.5}", rep.err_before()),
            format!("{:.5}", rep.err_after()),
            format!("{:.2}", rep.improvement() * 100.0),
            rep.rounds_run.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false).map_err(|e| anyhow::anyhow!("{e}"))?;
    if args.has_flag("refine") {
        return refine_demo(&args);
    }
    let model = args.get_str("model", "small");
    let artifacts = PathBuf::from(args.get_str("artifacts", "artifacts"));
    let runs = PathBuf::from(args.get_str("runs", "runs"));
    let mut lab = Lab::new(&artifacts, &runs)?;
    lab.task_items = args.get_usize("items", 30).unwrap_or(30);

    let ranks: Vec<usize> = args
        .get_list("ranks", &["0", "1", "4", "8", "16"])
        .iter()
        .filter_map(|s| s.parse().ok())
        .collect();

    let fig1 = experiments::fig1(&lab, &model, &ranks)?;
    fig1.print();
    fig1.append_to(&runs.join("results.md"))?;

    let max_rank = args.get_usize("max-rank", 4).unwrap_or(4);
    let fig3 = experiments::fig3(&lab, &model, max_rank)?;
    fig3.print();
    fig3.append_to(&runs.join("results.md"))?;
    Ok(())
}
