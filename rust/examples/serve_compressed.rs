//! Serving demo: batched generation over the serving engines — AOT
//! artifacts (dense and SLaB-reconstructed weights), the native packed
//! backend that consumes the compressed format directly, and the same
//! packed engine behind the continuous-batching scheduler.
//!
//! Spawns client threads that submit generation requests; the router
//! batches them (dynamic batching for the first three, continuous
//! batching for the fourth), reports throughput, latency percentiles,
//! batch occupancy, and the deployed-weight byte ratio.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_compressed -- [--model small] [--requests 24]
//! ```

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

use slab::baselines::Method;
use slab::coordinator::{
    compress_model, Backend, Engine, Event, Request, SchedulerConfig, Server, ServerConfig,
};
use slab::experiments::Lab;
use slab::model::SlabModel;
use slab::slab::SlabConfig;
use slab::util::cli::Args;
use std::path::PathBuf;

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    sorted[((sorted.len() as f64 - 1.0) * q) as usize]
}

fn run_server(server: Server, prompts: &[Vec<i32>], label: &str) -> anyhow::Result<()> {
    // Clients submit concurrently; each gets a streaming Session and
    // drains it blocking-style (`collect()` — the historical
    // whole-completion semantics, token-identical to streaming).
    let t0 = std::time::Instant::now();
    let sessions: Vec<_> = prompts
        .iter()
        .map(|p| {
            server.submit(Request {
                prompt: p.clone(),
                max_new: 16,
                deadline: None,
            })
        })
        .collect();
    let mut lat: Vec<f64> = Vec::new();
    let mut toks = 0usize;
    for session in sessions {
        let r = session.collect();
        lat.push(r.latency_ms);
        toks += r.tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown().map_err(|e| anyhow::anyhow!("{e}"))?;
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "[{label}] {} req / {} batches (occ {:.2}) — {:.1} gen-tok/s, ttft {:.1} ms, latency p50 {:.0} ms p95 {:.0} ms, {} tokens in {:.1}s",
        stats.requests,
        stats.batches,
        stats.occupancy(4),
        stats.tokens_per_sec(),
        stats.mean_ttft_ms(),
        percentile(&lat, 0.5),
        percentile(&lat, 0.95),
        toks,
        wall
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false).map_err(|e| anyhow::anyhow!("{e}"))?;
    let model = args.get_str("model", "small");
    let n_req = args.get_usize("requests", 24).unwrap_or(24);
    let artifacts = PathBuf::from(args.get_str("artifacts", "artifacts"));
    let runs = PathBuf::from(args.get_str("runs", "runs"));

    // IMPORTANT: xla_extension 0.5.1 segfaults when two PJRT CPU
    // clients coexist in one process, so the compression phase (which
    // owns a client via Lab) is scoped to finish — and its client to
    // drop — before each Server spins up its own client in the router
    // thread.
    let (dense, compressed, slab_layers, prompts) = {
        let lab = Lab::new(&artifacts, &runs)?;
        let dense = lab.dense_params(&model, lab.default_steps(&model))?;
        let corpus = lab.corpus(&model);
        let slab_model = compress_model(
            &lab.rt,
            &dense,
            &corpus.calib,
            &Method::Slab(SlabConfig::default()),
            Engine::Artifact,
        )?;
        // Deployed-weight accounting (packed CSR + bitplane + rank-1).
        let dense_bytes: usize = slab_model
            .slab_layers
            .iter()
            .map(|(_, l)| l.dout() * l.din() * 4)
            .sum();
        let packed_bytes: usize = slab_model
            .slab_layers
            .iter()
            .map(|(_, l)| l.nbytes_deploy())
            .sum();
        println!(
            "compressed {} linears: packed {:.2} MiB vs dense {:.2} MiB ({:.2}x smaller)",
            slab_model.slab_layers.len(),
            packed_bytes as f64 / (1 << 20) as f64,
            dense_bytes as f64 / (1 << 20) as f64,
            dense_bytes as f64 / packed_bytes as f64
        );
        let mut rng = slab::util::rng::Pcg64::seed_from_u64(31);
        let prompts: Vec<Vec<i32>> = (0..n_req)
            .map(|_| lab.grammar.sample_sentence(&mut rng))
            .collect();
        (dense, slab_model.params, slab_model.slab_layers, prompts)
    }; // ← lab (and its PJRT client) dropped here

    // 1) AOT artifacts over the dense model.
    run_server(
        Server::start(artifacts.clone(), dense.clone(), ServerConfig::default()),
        &prompts,
        "dense-artifact",
    )?;
    // 2) AOT artifacts over the reconstructed Ŵ (smaller checkpoint,
    //    dense request-time compute).
    run_server(
        Server::start(artifacts.clone(), compressed, ServerConfig::default()),
        &prompts,
        "slab-artifact",
    )?;
    // 3) Native packed engine: serves straight from W_S + u vᵀ ⊙ W_B,
    //    no PJRT client, parallel blocked kernels.
    let native = SlabModel::from_packed(&dense, &slab_layers, 0);
    println!(
        "native packed engine: {} packed linears, {:.2} MiB resident weights",
        native.packed_linear_count(),
        native.weights_nbytes() as f64 / (1 << 20) as f64
    );
    run_server(
        Server::start_with(Backend::NativePacked(Box::new(native)), ServerConfig::default()),
        &prompts,
        "slab-native-packed",
    )?;
    // 4) The same packed engine behind the continuous-batching
    //    scheduler: prefill-then-join admission, shared decode passes,
    //    bounded-queue backpressure — token-identical responses,
    //    higher decode throughput under concurrent load.
    let batched = SlabModel::from_packed(&dense, &slab_layers, 0);
    let scfg = ServerConfig {
        sched: SchedulerConfig {
            max_batch: 8,
            ..Default::default()
        },
        ..Default::default()
    };
    run_server(
        Server::start_with(Backend::NativeBatched(Box::new(batched)), scfg),
        &prompts,
        "slab-native-batched",
    )?;
    // 5) The streaming session API on the same engine: consume one
    //    request's event stream token-by-token as the scheduler emits
    //    it, then cancel a second session mid-stream (its KV slot
    //    frees immediately). `slab serve --http` exposes exactly this
    //    over a socket.
    let streaming = SlabModel::from_packed(&dense, &slab_layers, 0);
    let server = Server::start_with(
        Backend::NativeBatched(Box::new(streaming)),
        ServerConfig::default(),
    );
    let session = server.submit(Request {
        prompt: prompts[0].clone(),
        max_new: 16,
        deadline: None,
    });
    print!("[stream] tokens:");
    let mut streamed = 0usize;
    while let Some(ev) = session.recv() {
        match ev {
            Event::Token(t) => {
                print!(" {t}");
                streamed += 1;
            }
            Event::Done(s) => println!(" — done ({streamed} tokens, ttft {:.2} ms)", s.ttft_ms),
            Event::Evicted(s) => println!(" — evicted after {} tokens", s.tokens),
            Event::Rejected => println!(" — rejected (queue full)"),
        }
    }
    let long = server.submit(Request {
        prompt: prompts[1].clone(),
        max_new: 16,
        deadline: None,
    });
    long.cancel();
    let r = long.collect();
    println!(
        "[stream] cancelled session kept {} token(s) (cancelled={})",
        r.tokens.len(),
        r.cancelled
    );
    server.shutdown().map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(())
}
