//! END-TO-END DRIVER — proves all three layers compose on a real
//! workload:
//!
//! 1. **Train** a Llama-architecture model from scratch on the
//!    synthetic corpus by driving the AOT `train_step` artifact
//!    (fwd+bwd+AdamW in XLA) from rust, logging the loss curve.
//! 2. **Compress** it one-shot with SLaB through the layer-wise
//!    pipeline (calibration forwards + the Pallas `decompose`
//!    artifact) and with the Wanda/SparseGPT baselines natively.
//! 3. **Evaluate** perplexity + the seven zero-shot suites for every
//!    variant and print a Table-I-shaped comparison.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train_compress_eval -- [--model small] [--steps 300]
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

use slab::baselines::{Method, SparseGptConfig};
use slab::coordinator::{compress_model, Engine};
use slab::eval::{perplexity, zero_shot};
use slab::experiments::Lab;
use slab::model::Params;
use slab::report::Table;
use slab::slab::SlabConfig;
use slab::train::train;
use slab::util::cli::Args;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false).map_err(|e| anyhow::anyhow!("{e}"))?;
    let model = args.get_str("model", "small");
    let artifacts = PathBuf::from(args.get_str("artifacts", "artifacts"));
    let runs = PathBuf::from(args.get_str("runs", "runs"));
    let mut lab = Lab::new(&artifacts, &runs)?;
    lab.task_items = args.get_usize("items", 40).unwrap_or(40);

    let cfg = lab
        .rt
        .manifest
        .config(&model)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?
        .clone();
    let steps = args
        .get_usize("steps", lab.default_steps(&model))
        .unwrap_or(300);

    println!("== e2e: {} ({} params, {} layers, d={}) ==", cfg.name, cfg.n_params(), cfg.n_layers, cfg.dim);

    // ---- 1. train -------------------------------------------------------
    let corpus = lab.corpus(&model);
    let init = Params::init(&cfg, 0x1417 ^ slab::experiments::CORPUS_SEED);
    let (dense, report) = train(&lab.rt, &init, &corpus.train, steps, lab.seed, 20)?;
    println!(
        "trained {} steps in {:.1}s ({:.0} tok/s); loss {:.3} → {:.3}",
        report.steps,
        report.wall_secs,
        report.tokens_per_sec,
        report.loss_curve.first().map(|x| x.1).unwrap_or(f32::NAN),
        report.final_loss
    );
    let mut curve = Table::new("Loss curve", &["step", "loss"]);
    for (s, l) in &report.loss_curve {
        curve.push_row(vec![s.to_string(), format!("{l:.4}")]);
    }
    curve.print();
    std::fs::create_dir_all(&runs)?;
    dense.save(&runs.join(format!("{model}.slabckpt")))?;

    // ---- 2+3. compress & evaluate every method ---------------------------
    let suites = lab.suites();
    let mut table = Table::new(
        &format!("E2E comparison — {model}, US 50% (+ dense reference)"),
        &["Method", "ppl↓", "acc↑", "compress s"],
    );
    let methods: Vec<(Method, Engine)> = vec![
        (Method::Dense, Engine::Native),
        (
            Method::SparseGpt {
                sparsity: 0.5,
                pattern: None,
                cfg: SparseGptConfig::default(),
            },
            Engine::Native,
        ),
        (
            Method::Wanda {
                sparsity: 0.5,
                pattern: None,
            },
            Engine::Native,
        ),
        // SLaB through the AOT Pallas decompose artifact — the full
        // L1→L2→L3 composition.
        (Method::Slab(SlabConfig::default()), Engine::Artifact),
    ];
    for (m, engine) in methods {
        let t0 = std::time::Instant::now();
        let params = if matches!(m, Method::Dense) {
            dense.clone()
        } else {
            compress_model(&lab.rt, &dense, &corpus.calib, &m, engine)?.params
        };
        let secs = t0.elapsed().as_secs_f64();
        let ppl = perplexity(&lab.rt, &params, &corpus.valid)?;
        let (_, acc) = zero_shot(&lab.rt, &params, &suites)?;
        println!("{:<10} ppl {:>8.3}  acc {:>5.1}%  ({secs:.1}s)", m.name(), ppl, acc * 100.0);
        table.push_row(vec![
            m.name(),
            Table::metric(ppl),
            Table::pct(acc),
            format!("{secs:.1}"),
        ]);
    }
    table.print();
    table.append_to(&runs.join("e2e.md"))?;
    println!("done — results appended to {}", runs.join("e2e.md").display());
    Ok(())
}
