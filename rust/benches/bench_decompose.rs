//! Compression-time benches: Algorithm 1 (native + AOT Pallas
//! artifact) against the baselines, across layer shapes and iteration
//! counts — plus the staged pipeline end to end, serial vs
//! layer-parallel, writing a machine-readable summary to
//! `BENCH_decompose.json` (CI's bench-smoke job uploads it alongside
//! `BENCH_serve.json`). This is the pipeline's dominant cost at
//! `slab compress` time. `SLAB_BENCH_FAST=1` shrinks everything to a
//! smoke run.

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

use slab::baselines::{magnitude_prune, sparsegpt_prune, wanda_prune, Method, SparseGptConfig};
use slab::coordinator::{BudgetConfig, BudgetPlan, CompressJob, LayerProbe};
use slab::data::TokenSet;
use slab::model::Params;
use slab::runtime::ModelCfg;
use slab::slab::threshold::sorted_scores_desc;
use slab::slab::{
    decompose, decompose_par, refine, wanda_scores, ActStats, RefineConfig, SlabConfig,
};
use slab::tensor::Mat;
use slab::util::bench::Bench;
use slab::util::json::Json;
use slab::util::pool::ThreadPool;
use slab::util::rng::Pcg64;
use std::path::Path;

/// One staged-pipeline run; returns (best wall secs over `reps`,
/// peak-bytes proxy of the last run).
fn run_pipeline(
    params: &Params,
    calib: &TokenSet,
    method: &Method,
    threads: usize,
    stream: Option<&Path>,
    reps: usize,
) -> (f64, usize) {
    let mut best = f64::INFINITY;
    let mut peak = 0usize;
    for _ in 0..reps.max(1) {
        let mut job = CompressJob::new(params, calib, method).threads(threads);
        if let Some(p) = stream {
            job = job.keep_dense(false).keep_packed(false).stream_to(p.to_path_buf());
        }
        let out = job.run().expect("compress job");
        best = best.min(out.report.wall_secs);
        peak = out.report.peak_bytes;
    }
    (best, peak)
}

fn main() {
    let fast = std::env::var("SLAB_BENCH_FAST").as_deref() == Ok("1");
    let mut rng = Pcg64::seed_from_u64(88);
    // One pool for every parallel row in this bench (spawning/joining
    // worker threads per group would pollute the timings).
    let pool = ThreadPool::new(0);

    for (dout, din) in [(256usize, 256usize), (688, 256)] {
        let mut b = Bench::new(&format!("decompose {dout}x{din}"));
        let w = Mat::randn(dout, din, 0.02, &mut rng);
        let x = Mat::randn(512, din, 1.0, &mut rng);
        let stats = ActStats::from_activations(&x);
        let stats_gram = ActStats::from_activations_with_gram(&x);
        let numel = (dout * din) as f64;

        for iters in [1usize, 5, 20] {
            let cfg = SlabConfig {
                iters,
                ..Default::default()
            };
            b.run_throughput(&format!("slab native s={iters}"), numel, "elem", || {
                decompose(&w, &stats, &cfg).expect("decompose")
            });
        }
        b.run_throughput("wanda", numel, "elem", || {
            wanda_prune(&w, &stats, 0.5, None)
        });
        b.run_throughput("magnitude", numel, "elem", || {
            magnitude_prune(&w, 0.5, None)
        });
        b.run_throughput("sparsegpt (OBS)", numel, "elem", || {
            sparsegpt_prune(&w, &stats_gram, 0.5, None, &SparseGptConfig::default())
                .expect("sparsegpt")
        });

        // Inner row-parallelism of a single decomposition (the
        // low-rank-binary materialization + Wanda scoring loops),
        // bit-identical to serial by construction.
        let cfg_par = SlabConfig { iters: 5, ..Default::default() };
        b.run_throughput(
            &format!("slab native s=5 par x{}", pool.size()),
            numel,
            "elem",
            || decompose_par(&w, &stats, &cfg_par, Some(&pool)).expect("decompose_par"),
        );

        // Design-choice ablation (DESIGN.md §8 / EXPERIMENTS.md §Perf):
        // O(n) partition vs O(n log n) full sort inside the threshold —
        // the hottest native loop of the 20-iteration Alg-1 sweep.
        let scores = w.abs();
        b.run_throughput("threshold select_nth (ours)", numel, "elem", || {
            slab::slab::threshold::group_topk_mask(&scores, 0.4355, 1, din)
        });
        b.run_throughput("threshold full-sort (ablation)", numel, "elem", || {
            slab::slab::threshold::group_topk_mask_sort(&scores, 0.4355)
        });
        b.finish();
    }

    // --- staged pipeline: serial vs layer-parallel, keep vs stream ----
    // The ISSUE-3 acceptance row: whole-model compression through
    // CompressJob at ≥2 block counts, serial wall-clock vs the
    // scoped-worker decompose fan-out (bit-identical outputs), plus
    // the peak-resident proxy for keep-everything vs streaming emit.
    let reps = if fast { 1 } else { 3 };
    let (dim, ffn, seq) = if fast { (48, 96, 16) } else { (96, 192, 24) };
    let calib_rows = if fast { 4 } else { 8 };
    let iters = if fast { 2 } else { 4 };
    let mut rows: Vec<Json> = Vec::new();
    println!("\n== bench group: staged compression pipeline ==");
    for n_layers in [2usize, 4] {
        let cfg = ModelCfg::llama(
            &format!("bench-compress-{n_layers}"),
            64,
            dim,
            n_layers,
            4,
            ffn,
            seq,
            8,
        );
        let params = Params::init(&cfg, 99);
        let calib = TokenSet::synthetic(calib_rows, cfg.max_seq, cfg.vocab);
        let method = Method::Slab(SlabConfig {
            iters,
            svd_iters: 8,
            ..Default::default()
        });
        let (serial_s, serial_peak) = run_pipeline(&params, &calib, &method, 1, None, reps);
        let (par_s, _) = run_pipeline(&params, &calib, &method, 0, None, reps);
        let stream_path = std::env::temp_dir().join(format!("slab-bench/stream-{n_layers}.slabckpt"));
        let (stream_s, stream_peak) =
            run_pipeline(&params, &calib, &method, 0, Some(&stream_path), reps);
        let speedup = serial_s / par_s.max(1e-9);
        println!(
            "blocks={n_layers}: serial {serial_s:.2}s vs parallel {par_s:.2}s ({speedup:.2}x); \
             peak keep {:.1} MiB vs stream {:.1} MiB",
            serial_peak as f64 / (1 << 20) as f64,
            stream_peak as f64 / (1 << 20) as f64
        );
        rows.push(Json::obj(vec![
            ("blocks", Json::from_usize(n_layers)),
            ("dim", Json::from_usize(cfg.dim)),
            ("ffn", Json::from_usize(cfg.ffn)),
            ("serial_secs", Json::num(serial_s)),
            ("parallel_secs", Json::num(par_s)),
            ("stream_secs", Json::num(stream_s)),
            ("speedup_parallel_vs_serial", Json::num(speedup)),
            ("peak_bytes_keep", Json::from_usize(serial_peak)),
            ("peak_bytes_stream", Json::from_usize(stream_peak)),
        ]));
    }
    // --- joint refinement + activation-aware allocation ---------------
    // ISSUE-10 rows: refinement throughput on a representative layer,
    // and the headline quality claim — alloc+refined activation-weighted
    // error below the one-shot uniform fit at an *exactly equal* global
    // keep budget. `rounds_per_sec` is a `*_per_sec` leaf, so the CI
    // perf gate pins it automatically once a baseline lands.
    println!("\n== bench group: refinement + budget allocation ==");
    let (rdout, rdin) = if fast { (96usize, 128usize) } else { (256usize, 256usize) };
    let rw = Mat::randn(rdout, rdin, 0.02, &mut rng);
    let rx = Mat::randn(if fast { 64 } else { 256 }, rdin, 1.0, &mut rng);
    let rstats = ActStats::from_activations(&rx);
    let rcfg_fit = SlabConfig { iters: if fast { 2 } else { 5 }, ..Default::default() };
    let rd = decompose(&rw, &rstats, &rcfg_fit).expect("decompose for refine bench");
    // tol 0 disables the relative-improvement early stop, so the timing
    // covers the configured round count (the accept guard can still
    // stop a non-improving round — `rounds_run` is what we divide by).
    let rc = RefineConfig { rounds: if fast { 2 } else { 6 }, tol: 0.0 };
    let t0 = std::time::Instant::now();
    let (_, rrep) = refine(&rw, &rd, &rstats, &rcfg_fit, &rc).expect("refine bench");
    let refine_secs = t0.elapsed().as_secs_f64().max(1e-9);
    let rounds_per_sec = rrep.rounds_run.max(1) as f64 / refine_secs;
    println!(
        "refine {rdout}x{rdin}: {} rounds in {refine_secs:.3}s ({rounds_per_sec:.2} rounds/s)",
        rrep.rounds_run
    );

    // Quality row: three linears with strongly heterogeneous activation
    // scales (the setting water-filling exists for). One-shot uniform
    // error comes from a rounds=0 refine (`err_trace[0]` is the fit
    // error before any refinement); the contender re-plans the same
    // global budget and refines each layer under its allocated config.
    let (qdout, qdin) = if fast { (48usize, 96usize) } else { (96usize, 192usize) };
    let qrows = if fast { 32 } else { 128 };
    let qlayers: Vec<(String, Mat, ActStats)> = [1.0f32, 0.3, 0.02]
        .iter()
        .enumerate()
        .map(|(i, &scale)| {
            let w = Mat::randn(qdout, qdin, 0.05, &mut rng);
            let x = Mat::randn(qrows, qdin, scale, &mut rng);
            (format!("lin{i}"), w, ActStats::from_activations(&x))
        })
        .collect();
    let probes: Vec<LayerProbe> = qlayers
        .iter()
        .map(|(name, w, stats)| LayerProbe {
            name: name.clone(),
            dout: qdout,
            din: qdin,
            scores: sorted_scores_desc(&wanda_scores(w, stats)),
        })
        .collect();
    let plan = BudgetPlan::plan(&probes, &rcfg_fit, &BudgetConfig::default()).expect("budget plan");
    assert_eq!(
        plan.total_keep(),
        plan.total_uniform_keep(),
        "allocator must conserve the global keep budget exactly"
    );
    let qrc = RefineConfig::with_rounds(if fast { 2 } else { 4 });
    let (mut oneshot_sq, mut refined_sq) = (0.0f64, 0.0f64);
    for (name, w, stats) in &qlayers {
        let du = decompose(w, stats, &rcfg_fit).expect("uniform decompose");
        let (_, r0) = refine(w, &du, stats, &rcfg_fit, &RefineConfig::with_rounds(0))
            .expect("rounds=0 probe");
        oneshot_sq += (r0.err_before() as f64).powi(2);
        let eff = plan.config_for(name);
        let da = decompose(w, stats, &eff).expect("alloc decompose");
        let (_, ra) = refine(w, &da, stats, &eff, &qrc).expect("alloc refine");
        refined_sq += (ra.err_after() as f64).powi(2);
    }
    let oneshot_werr = oneshot_sq.sqrt();
    let alloc_refined_werr = refined_sq.sqrt();
    assert!(
        alloc_refined_werr <= oneshot_werr,
        "alloc+refined werr {alloc_refined_werr} must not exceed one-shot uniform {oneshot_werr}"
    );
    let werr_improvement_frac = 1.0 - alloc_refined_werr / oneshot_werr.max(1e-12);
    println!(
        "alloc+refine vs one-shot uniform ({} layers {qdout}x{qdin}): \
         werr {alloc_refined_werr:.5} vs {oneshot_werr:.5} ({:.2}% better, equal budget)",
        qlayers.len(),
        werr_improvement_frac * 100.0
    );
    let refine_obj = Json::obj(vec![
        ("layer", Json::str(format!("{rdout}x{rdin}"))),
        ("rounds_run", Json::from_usize(rrep.rounds_run)),
        ("rounds_per_sec", Json::num(rounds_per_sec)),
        ("oneshot_werr", Json::num(oneshot_werr)),
        ("alloc_refined_werr", Json::num(alloc_refined_werr)),
        ("werr_improvement_frac", Json::num(werr_improvement_frac)),
    ]);

    let summary = Json::obj(vec![
        ("bench", Json::str("compress_pipeline")),
        ("threads_parallel", Json::from_usize(pool.size())),
        ("configs", Json::arr(rows)),
        ("refine", refine_obj),
    ]);
    std::fs::write("BENCH_decompose.json", summary.to_pretty())
        .expect("write BENCH_decompose.json");
    println!("wrote BENCH_decompose.json");

    // AOT decompose artifact (Pallas inner kernel, XLA sort threshold).
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        if let Ok(rt) = slab::runtime::Runtime::new(dir) {
            let mut b = Bench::new("AOT decompose artifact (PJRT CPU)");
            for (dout, din) in [(128usize, 128usize), (344, 128), (128, 344)] {
                let name = format!("decompose_{dout}x{din}");
                if rt.manifest.artifact(&name).is_none() {
                    continue;
                }
                let w = Mat::randn(dout, din, 0.02, &mut rng);
                let sx = vec![1.0f32; din];
                let inputs = vec![
                    slab::runtime::lit_mat(&w),
                    slab::runtime::lit_f32(&sx, &[din]),
                    slab::runtime::literal::lit_scalar_f32(0.4355),
                    slab::runtime::lit_scalar_i32(20),
                ];
                b.run_throughput(&format!("{name} s=20"), (dout * din) as f64, "elem", || {
                    rt.execute(&name, &inputs).expect("exec")
                });
            }
            b.finish();
        }
    } else {
        eprintln!("(artifacts/ missing — skipping AOT decompose benches)");
    }
}
