//! Compression-time benches: Algorithm 1 (native + AOT Pallas
//! artifact) against the baselines, across layer shapes and iteration
//! counts. This is the pipeline's dominant cost at `slab compress`
//! time.

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

use slab::baselines::{magnitude_prune, sparsegpt_prune, wanda_prune, SparseGptConfig};
use slab::slab::{decompose, ActStats, SlabConfig};
use slab::tensor::Mat;
use slab::util::bench::Bench;
use slab::util::rng::Pcg64;
use std::path::Path;

fn main() {
    let mut rng = Pcg64::seed_from_u64(88);

    for (dout, din) in [(256usize, 256usize), (688, 256)] {
        let mut b = Bench::new(&format!("decompose {dout}x{din}"));
        let w = Mat::randn(dout, din, 0.02, &mut rng);
        let x = Mat::randn(512, din, 1.0, &mut rng);
        let stats = ActStats::from_activations(&x);
        let stats_gram = ActStats::from_activations_with_gram(&x);
        let numel = (dout * din) as f64;

        for iters in [1usize, 5, 20] {
            let cfg = SlabConfig {
                iters,
                ..Default::default()
            };
            b.run_throughput(&format!("slab native s={iters}"), numel, "elem", || {
                decompose(&w, &stats, &cfg).expect("decompose")
            });
        }
        b.run_throughput("wanda", numel, "elem", || {
            wanda_prune(&w, &stats, 0.5, None)
        });
        b.run_throughput("magnitude", numel, "elem", || {
            magnitude_prune(&w, 0.5, None)
        });
        b.run_throughput("sparsegpt (OBS)", numel, "elem", || {
            sparsegpt_prune(&w, &stats_gram, 0.5, None, &SparseGptConfig::default())
                .expect("sparsegpt")
        });

        // Design-choice ablation (DESIGN.md §8 / EXPERIMENTS.md §Perf):
        // O(n) partition vs O(n log n) full sort inside the threshold —
        // the hottest native loop of the 20-iteration Alg-1 sweep.
        let scores = w.abs();
        b.run_throughput("threshold select_nth (ours)", numel, "elem", || {
            slab::slab::threshold::group_topk_mask(&scores, 0.4355, 1, din)
        });
        b.run_throughput("threshold full-sort (ablation)", numel, "elem", || {
            slab::slab::threshold::group_topk_mask_sort(&scores, 0.4355)
        });
        b.finish();
    }

    // AOT decompose artifact (Pallas inner kernel, XLA sort threshold).
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        if let Ok(rt) = slab::runtime::Runtime::new(dir) {
            let mut b = Bench::new("AOT decompose artifact (PJRT CPU)");
            for (dout, din) in [(128usize, 128usize), (344, 128), (128, 344)] {
                let name = format!("decompose_{dout}x{din}");
                if rt.manifest.artifact(&name).is_none() {
                    continue;
                }
                let w = Mat::randn(dout, din, 0.02, &mut rng);
                let sx = vec![1.0f32; din];
                let inputs = vec![
                    slab::runtime::lit_mat(&w),
                    slab::runtime::lit_f32(&sx, &[din]),
                    slab::runtime::literal::lit_scalar_f32(0.4355),
                    slab::runtime::lit_scalar_i32(20),
                ];
                b.run_throughput(&format!("{name} s=20"), (dout * din) as f64, "elem", || {
                    rt.execute(&name, &inputs).expect("exec")
                });
            }
            b.finish();
        }
    } else {
        eprintln!("(artifacts/ missing — skipping AOT decompose benches)");
    }
}
