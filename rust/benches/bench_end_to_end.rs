//! End-to-end benches over the AOT artifacts: train-step latency,
//! eval throughput, and serving (prefill + decode) tokens/sec.
//! Skips gracefully when `artifacts/` is missing.

use slab::data::{build_corpus, Grammar};
use slab::model::Params;
use slab::runtime::{lit_i32, lit_scalar_i32, Runtime};
use slab::util::bench::Bench;
use std::path::Path;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first; skipping");
        return;
    }
    let rt = Runtime::new(dir).expect("runtime");
    let cfg = rt.manifest.config("small").expect("small config").clone();
    let g = Grammar::standard();
    let corpus = build_corpus(&g, 42, 64, 32, 32, cfg.max_seq);
    let params = Params::init(&cfg, 7);

    let mut b = Bench::new(&format!("end-to-end ({}, {} params)", cfg.name, cfg.n_params()));

    // --- train step ------------------------------------------------------
    {
        let name = format!("train_step_{}", cfg.name);
        let bsz = rt.manifest.train_batch;
        let width = cfg.max_seq + 1;
        let tokens_per_step = (bsz * cfg.max_seq) as f64;
        let zero = Params::zeros_like(&cfg);
        b.run_throughput("train_step", tokens_per_step, "tok", || {
            let mut inputs = params.to_literals();
            inputs.extend(zero.to_literals());
            inputs.extend(zero.to_literals());
            inputs.push(lit_scalar_i32(0));
            inputs.push(lit_i32(&corpus.train.batch(0, bsz), &[bsz, width]));
            rt.execute(&name, &inputs).expect("train_step")
        });
    }

    // --- eval_nll ----------------------------------------------------------
    {
        let name = format!("eval_nll_{}", cfg.name);
        let bsz = rt.manifest.eval_batch;
        let width = cfg.max_seq + 1;
        b.run_throughput("eval_nll batch", (bsz * cfg.max_seq) as f64, "tok", || {
            let mut inputs = params.to_literals();
            inputs.push(lit_i32(&corpus.valid.batch(0, bsz), &[bsz, width]));
            rt.execute(&name, &inputs).expect("eval_nll")
        });
    }

    // --- prefill + decode ---------------------------------------------------
    {
        let prefill = format!("prefill_{}", cfg.name);
        let decode = format!("decode_step_{}", cfg.name);
        let sb = rt.manifest.serve_batch;
        let pl = cfg.prompt_len;
        let prompt: Vec<i32> = corpus.valid.row(0)[..pl]
            .iter()
            .cycle()
            .take(sb * pl)
            .copied()
            .collect();
        b.run_throughput("prefill", (sb * pl) as f64, "tok", || {
            let mut inputs = params.to_literals();
            inputs.push(lit_i32(&prompt, &[sb, pl]));
            rt.execute(&prefill, &inputs).expect("prefill")
        });
        // One decode step, caches from a single prefill.
        let mut inputs = params.to_literals();
        inputs.push(lit_i32(&prompt, &[sb, pl]));
        let outs = rt.execute(&prefill, &inputs).expect("prefill once");
        let kc = &outs[1];
        let vc = &outs[2];
        let tok = vec![5i32; sb];
        b.run_throughput("decode_step", sb as f64, "tok", || {
            let mut inputs = params.to_literals();
            inputs.push(clone(kc));
            inputs.push(clone(vc));
            inputs.push(lit_i32(&tok, &[sb]));
            inputs.push(lit_scalar_i32(pl as i32));
            rt.execute(&decode, &inputs).expect("decode")
        });
    }

    b.finish();
}

fn clone(l: &xla::Literal) -> xla::Literal {
    let v = l.to_vec::<f32>().unwrap();
    let dims: Vec<i64> = l.array_shape().unwrap().dims().to_vec();
    xla::Literal::vec1(&v).reshape(&dims).unwrap()
}
