//! End-to-end benches: native packed serving (serial vs
//! continuous-batched decode — runs on every machine, no artifacts),
//! then the AOT-artifact path (train-step latency, eval throughput,
//! serving tokens/sec) when `artifacts/` is present.

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

mod bench_common;

use bench_common::compress_native;
use slab::data::{build_corpus, Grammar};
use slab::model::{DecodeSlot, KvCachePool, Params, SlabModel};
use slab::runtime::{lit_i32, lit_scalar_i32, ModelCfg, Runtime};
use slab::util::bench::Bench;
use std::path::Path;

fn main() {
    native_serving_bench();
    aot_bench();
}

/// The continuous-batching acceptance measurement: batched decode at
/// batch 8 vs eight serial `NativePacked`-style sessions, on a packed
/// engine heavy enough that the weight pass dominates. The batched
/// path reads every weight once per tick; the serial path reads it
/// eight times — the printed speedup is the amortization factor.
fn native_serving_bench() {
    let cfg = ModelCfg::llama("bench-e2e-native", 128, 256, 2, 4, 512, 96, 16);
    let params = Params::init(&cfg, 17);
    let packed = compress_native(&params, 18);
    let model = SlabModel::from_packed(&params, &packed, 0);
    let mut b = Bench::new(&format!(
        "native packed serving (dim {}, {} layers, {:.2} MiB)",
        cfg.dim,
        cfg.n_layers,
        model.weights_nbytes() as f64 / (1 << 20) as f64
    ));
    let pos = cfg.prompt_len;
    let tok = 5i32;
    let prompt = |i: usize| -> Vec<i32> {
        (0..cfg.prompt_len).map(|j| 5 + ((i + j) % 40) as i32).collect()
    };

    // Serial baseline: eight independent sessions, one decode_step each.
    let mut caches: Vec<_> = (0..8).map(|i| model.prefill_session(&prompt(i)).1).collect();
    let serial = b.run_throughput("serial decode_step x8 sessions", 8.0, "tok", || {
        for cache in caches.iter_mut() {
            model.decode_step(cache, &[tok], pos);
        }
    });

    // Continuous-batched: the same eight sessions through one shared
    // decode_batch pass per tick.
    let mut kv = KvCachePool::for_model(&model, 8);
    let steps: Vec<DecodeSlot> = (0..8)
        .map(|i| {
            let (_, cache) = model.prefill_session(&prompt(i));
            DecodeSlot {
                session: kv.adopt(cache).expect("pool capacity"),
                token: tok,
                pos,
            }
        })
        .collect();
    let batched = b.run_throughput("decode_batch x8 (continuous batching)", 8.0, "tok", || {
        model.decode_batch(&mut kv, &steps)
    });
    b.finish();
    println!(
        "[acceptance] batched x8 = {:.1} tok/s vs serial x8 = {:.1} tok/s → {:.2}x",
        batched.throughput(8.0),
        serial.throughput(8.0),
        batched.throughput(8.0) / serial.throughput(8.0).max(1e-9)
    );
}

fn aot_bench() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first; skipping AOT benches");
        return;
    }
    let rt = Runtime::new(dir).expect("runtime");
    let cfg = rt.manifest.config("small").expect("small config").clone();
    let g = Grammar::standard();
    let corpus = build_corpus(&g, 42, 64, 32, 32, cfg.max_seq);
    let params = Params::init(&cfg, 7);

    let mut b = Bench::new(&format!("end-to-end ({}, {} params)", cfg.name, cfg.n_params()));

    // --- train step ------------------------------------------------------
    {
        let name = format!("train_step_{}", cfg.name);
        let bsz = rt.manifest.train_batch;
        let width = cfg.max_seq + 1;
        let tokens_per_step = (bsz * cfg.max_seq) as f64;
        let zero = Params::zeros_like(&cfg);
        b.run_throughput("train_step", tokens_per_step, "tok", || {
            let mut inputs = params.to_literals();
            inputs.extend(zero.to_literals());
            inputs.extend(zero.to_literals());
            inputs.push(lit_scalar_i32(0));
            inputs.push(lit_i32(&corpus.train.batch(0, bsz), &[bsz, width]));
            rt.execute(&name, &inputs).expect("train_step")
        });
    }

    // --- eval_nll ----------------------------------------------------------
    {
        let name = format!("eval_nll_{}", cfg.name);
        let bsz = rt.manifest.eval_batch;
        let width = cfg.max_seq + 1;
        b.run_throughput("eval_nll batch", (bsz * cfg.max_seq) as f64, "tok", || {
            let mut inputs = params.to_literals();
            inputs.push(lit_i32(&corpus.valid.batch(0, bsz), &[bsz, width]));
            rt.execute(&name, &inputs).expect("eval_nll")
        });
    }

    // --- prefill + decode ---------------------------------------------------
    {
        let prefill = format!("prefill_{}", cfg.name);
        let decode = format!("decode_step_{}", cfg.name);
        let sb = rt.manifest.serve_batch;
        let pl = cfg.prompt_len;
        let prompt: Vec<i32> = corpus.valid.row(0)[..pl]
            .iter()
            .cycle()
            .take(sb * pl)
            .copied()
            .collect();
        b.run_throughput("prefill", (sb * pl) as f64, "tok", || {
            let mut inputs = params.to_literals();
            inputs.push(lit_i32(&prompt, &[sb, pl]));
            rt.execute(&prefill, &inputs).expect("prefill")
        });
        // One decode step, caches from a single prefill.
        let mut inputs = params.to_literals();
        inputs.push(lit_i32(&prompt, &[sb, pl]));
        let outs = rt.execute(&prefill, &inputs).expect("prefill once");
        let kc = &outs[1];
        let vc = &outs[2];
        let tok = vec![5i32; sb];
        b.run_throughput("decode_step", sb as f64, "tok", || {
            let mut inputs = params.to_literals();
            inputs.push(clone(kc));
            inputs.push(clone(vc));
            inputs.push(lit_i32(&tok, &[sb]));
            inputs.push(lit_scalar_i32(pl as i32));
            rt.execute(&decode, &inputs).expect("decode")
        });
    }

    b.finish();
}

fn clone(l: &xla::Literal) -> xla::Literal {
    let v = l.to_vec::<f32>().unwrap();
    let dims: Vec<i64> = l.array_shape().unwrap().dims().to_vec();
    xla::Literal::vec1(&v).reshape(&dims).unwrap()
}
