//! Batched-decode serving bench — the continuous-batching scheduler's
//! hot path over the *packed* engine.
//!
//! Measures decode tokens/s at batch 1 / 4 / 8 through
//! `SlabModel::decode_batch` (one shared weight pass per tick) against
//! the serial baseline of eight independent `decode_step` sessions
//! (eight weight passes per tick) — the CPU analogue of the
//! weight-streaming amortization argument in DESIGN.md §6a.
//!
//! Besides the human-readable table, writes a machine-readable summary
//! to `BENCH_serve.json` (CI's bench-smoke job uploads it as a
//! workflow artifact), so throughput regressions are diffable across
//! runs. `SLAB_BENCH_FAST=1` shrinks everything to a smoke run.

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

mod bench_common;

use bench_common::compress_native;
use slab::model::{DecodeSlot, KvCachePool, Params, SlabModel};
use slab::runtime::ModelCfg;
use slab::util::bench::Bench;
use slab::util::json::Json;

/// A deterministic valid prompt for session `i`.
fn bench_prompt(i: usize, len: usize) -> Vec<i32> {
    (0..len).map(|j| 5 + ((i + j) % 40) as i32).collect()
}

fn main() {
    // Big enough that the weight pass dominates per-call overhead,
    // small enough that a SLAB_BENCH_FAST smoke run stays in seconds.
    let cfg = ModelCfg::llama("bench-serve", 128, 128, 2, 4, 256, 96, 16);
    let params = Params::init(&cfg, 7);
    let packed = compress_native(&params, 8);
    let model = SlabModel::from_packed(&params, &packed, 0);
    println!(
        "bench-serve model: dim {}, {} layers, {} packed linears, {:.2} MiB resident",
        cfg.dim,
        cfg.n_layers,
        model.packed_linear_count(),
        model.weights_nbytes() as f64 / (1 << 20) as f64
    );

    let pos = cfg.prompt_len; // first decode position; rewritten per iter
    let tok = 5i32;
    let mut b = Bench::new("batched decode (packed engine)");
    let mut tps: Vec<(usize, f64)> = Vec::new();

    for bsz in [1usize, 4, 8] {
        let mut kv = KvCachePool::for_model(&model, bsz);
        let steps: Vec<DecodeSlot> = (0..bsz)
            .map(|i| {
                let (_, cache) = model.prefill_session(&bench_prompt(i, cfg.prompt_len));
                DecodeSlot {
                    session: kv.adopt(cache).expect("pool capacity"),
                    token: tok,
                    pos,
                }
            })
            .collect();
        let stats = b.run_throughput(&format!("decode_batch x{bsz}"), bsz as f64, "tok", || {
            model.decode_batch(&mut kv, &steps)
        });
        tps.push((bsz, stats.throughput(bsz as f64)));
    }

    // Serial baseline: eight independent single-session decode_step
    // calls per tick — what eight NativePacked servers would do.
    let serial_n = 8usize;
    let mut caches: Vec<_> = (0..serial_n)
        .map(|i| model.prefill_session(&bench_prompt(i, cfg.prompt_len)).1)
        .collect();
    let serial_stats = b.run_throughput(
        &format!("serial decode_step x{serial_n} sessions"),
        serial_n as f64,
        "tok",
        || {
            for cache in caches.iter_mut() {
                model.decode_step(cache, &[tok], pos);
            }
        },
    );
    let serial_tps = serial_stats.throughput(serial_n as f64);
    b.finish();

    let tps_for = |n: usize| {
        tps.iter().find(|(m, _)| *m == n).map(|(_, v)| *v).unwrap_or(0.0)
    };
    let speedup = tps_for(8) / serial_tps.max(1e-9);
    println!("batched x8 vs serial x8: {speedup:.2}x tokens/s");

    let summary = Json::obj(vec![
        ("bench", Json::str("serve_batched_decode")),
        (
            "model",
            Json::obj(vec![
                ("dim", Json::from_usize(cfg.dim)),
                ("n_layers", Json::from_usize(cfg.n_layers)),
                ("ffn", Json::from_usize(cfg.ffn)),
                ("vocab", Json::from_usize(cfg.vocab)),
                ("prompt_len", Json::from_usize(cfg.prompt_len)),
            ]),
        ),
        (
            "tokens_per_sec",
            Json::obj(vec![
                ("batch_1", Json::num(tps_for(1))),
                ("batch_4", Json::num(tps_for(4))),
                ("batch_8", Json::num(tps_for(8))),
            ]),
        ),
        ("serial_8_sessions_tokens_per_sec", Json::num(serial_tps)),
        ("speedup_batch8_vs_serial8", Json::num(speedup)),
    ]);
    std::fs::write("BENCH_serve.json", summary.to_pretty()).expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json");
}
