//! Batched-decode serving bench — the continuous-batching scheduler's
//! hot path over the *packed* engine.
//!
//! Measures decode tokens/s at batch 1 / 4 / 8 through
//! `SlabModel::decode_batch` (one shared weight pass per tick) against
//! the serial baseline of eight independent `decode_step` sessions
//! (eight weight passes per tick) — the CPU analogue of the
//! weight-streaming amortization argument in DESIGN.md §6a.
//!
//! Also measures the interactive serving surface (DESIGN.md §12):
//! client-side time-to-first-token through the streaming session API,
//! cancellation-under-load drain time (plus the TTFT of a fresh
//! request over the freed KV slots), and HTTP-loopback throughput
//! through `coordinator::http` over real sockets.
//!
//! Besides the human-readable table, writes a machine-readable summary
//! to `BENCH_serve.json` (CI's bench-smoke job uploads it as a
//! workflow artifact), so throughput regressions are diffable across
//! runs. `SLAB_BENCH_FAST=1` shrinks everything to a smoke run.

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

mod bench_common;

use bench_common::compress_native;
use slab::coordinator::http::client;
use slab::coordinator::{
    Backend, Event, HttpConfig, HttpServer, Request, SchedulerConfig, ServeStats, Server,
    ServerConfig,
};
use slab::model::{DecodeSlot, KvCachePool, PagedKvConfig, PagedKvPool, Params, SlabModel};
use slab::runtime::ModelCfg;
use slab::util::bench::Bench;
use slab::util::json::Json;
use std::time::{Duration, Instant};

/// A deterministic valid prompt for session `i`.
fn bench_prompt(i: usize, len: usize) -> Vec<i32> {
    (0..len).map(|j| 5 + ((i + j) % 40) as i32).collect()
}

fn main() {
    // Big enough that the weight pass dominates per-call overhead,
    // small enough that a SLAB_BENCH_FAST smoke run stays in seconds.
    let cfg = ModelCfg::llama("bench-serve", 128, 128, 2, 4, 256, 96, 16);
    let params = Params::init(&cfg, 7);
    let packed = compress_native(&params, 8);
    let model = SlabModel::from_packed(&params, &packed, 0);
    println!(
        "bench-serve model: dim {}, {} layers, {} packed linears, {:.2} MiB resident",
        cfg.dim,
        cfg.n_layers,
        model.packed_linear_count(),
        model.weights_nbytes() as f64 / (1 << 20) as f64
    );

    let pos = cfg.prompt_len; // first decode position; rewritten per iter
    let tok = 5i32;
    let mut b = Bench::new("batched decode (packed engine)");
    let mut tps: Vec<(usize, f64)> = Vec::new();

    for bsz in [1usize, 4, 8] {
        let mut kv = KvCachePool::for_model(&model, bsz);
        let steps: Vec<DecodeSlot> = (0..bsz)
            .map(|i| {
                let (_, cache) = model.prefill_session(&bench_prompt(i, cfg.prompt_len));
                DecodeSlot {
                    session: kv.adopt(cache).expect("pool capacity"),
                    token: tok,
                    pos,
                }
            })
            .collect();
        let stats = b.run_throughput(&format!("decode_batch x{bsz}"), bsz as f64, "tok", || {
            model.decode_batch(&mut kv, &steps)
        });
        tps.push((bsz, stats.throughput(bsz as f64)));
    }

    // Serial baseline: eight independent single-session decode_step
    // calls per tick — what eight NativePacked servers would do.
    let serial_n = 8usize;
    let mut caches: Vec<_> = (0..serial_n)
        .map(|i| model.prefill_session(&bench_prompt(i, cfg.prompt_len)).1)
        .collect();
    let serial_stats = b.run_throughput(
        &format!("serial decode_step x{serial_n} sessions"),
        serial_n as f64,
        "tok",
        || {
            for cache in caches.iter_mut() {
                model.decode_step(cache, &[tok], pos);
            }
        },
    );
    let serial_tps = serial_stats.throughput(serial_n as f64);
    b.finish();

    let tps_for = |n: usize| {
        tps.iter().find(|(m, _)| *m == n).map(|(_, v)| *v).unwrap_or(0.0)
    };
    let speedup = tps_for(8) / serial_tps.max(1e-9);
    println!("batched x8 vs serial x8: {speedup:.2}x tokens/s");

    let fast = std::env::var("SLAB_BENCH_FAST").as_deref() == Ok("1");

    // --- streaming time-to-first-token (session API) ------------------
    // Client-side TTFT: submit → first Token event, over the full
    // Server + Scheduler stack (prefill-then-join admission included).
    let server = Server::start_with(
        Backend::NativeBatched(Box::new(SlabModel::from_packed(&params, &packed, 0))),
        ServerConfig::default(),
    );
    let ttft_reqs = if fast { 4 } else { 16 };
    let mut ttft_samples: Vec<f64> = Vec::new();
    for i in 0..ttft_reqs {
        let t0 = Instant::now();
        let session = server.submit(Request {
            prompt: bench_prompt(i, cfg.prompt_len),
            max_new: 8,
            deadline: None,
        });
        let mut first = None;
        while let Some(ev) = session.recv() {
            match ev {
                Event::Token(_) => {
                    if first.is_none() {
                        first = Some(t0.elapsed().as_secs_f64() * 1e3);
                    }
                }
                _ => break, // terminal
            }
        }
        if let Some(ms) = first {
            ttft_samples.push(ms);
        }
    }
    server.shutdown().expect("ttft server stats");
    let ttft_mean = ttft_samples.iter().sum::<f64>() / ttft_samples.len().max(1) as f64;
    println!(
        "streaming ttft: {ttft_mean:.2} ms mean over {} requests",
        ttft_samples.len()
    );

    // --- cancellation under load --------------------------------------
    // Fill the batch with long-budget sessions, cancel them all
    // mid-decode, and measure (a) how fast the scheduler drains them
    // and (b) the TTFT of a fresh request over the freed slots.
    let server = Server::start_with(
        Backend::NativeBatched(Box::new(SlabModel::from_packed(&params, &packed, 0))),
        ServerConfig {
            sched: SchedulerConfig {
                max_batch: 4,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let n_long = 8usize;
    let long_budget = cfg.max_seq - cfg.prompt_len;
    let sessions: Vec<_> = (0..n_long)
        .map(|i| {
            server.submit(Request {
                prompt: bench_prompt(i, cfg.prompt_len),
                max_new: long_budget,
                deadline: None,
            })
        })
        .collect();
    // Let the batch fill and decode a little before the purge.
    std::thread::sleep(Duration::from_millis(if fast { 5 } else { 20 }));
    let t_cancel = Instant::now();
    for s in &sessions {
        s.cancel();
    }
    for s in sessions {
        let _ = s.collect();
    }
    let cancel_drain_ms = t_cancel.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    let session = server.submit(Request {
        prompt: bench_prompt(0, cfg.prompt_len),
        max_new: 4,
        deadline: None,
    });
    let mut post_cancel_ttft_ms = 0.0;
    while let Some(ev) = session.recv() {
        match ev {
            Event::Token(_) => {
                if post_cancel_ttft_ms == 0.0 {
                    post_cancel_ttft_ms = t0.elapsed().as_secs_f64() * 1e3;
                }
            }
            _ => break,
        }
    }
    let cancel_stats = server.shutdown().expect("cancel server stats");
    println!(
        "cancel-under-load: drained {n_long} long sessions in {cancel_drain_ms:.2} ms \
         ({} cancelled), post-cancel ttft {post_cancel_ttft_ms:.2} ms",
        cancel_stats.cancelled
    );

    // --- HTTP loopback throughput -------------------------------------
    // The whole wire path: JSON parse → session → stream → JSON reply,
    // sequential blocking requests over real sockets.
    let http = HttpServer::bind(
        "127.0.0.1:0",
        Server::start_with(
            Backend::NativeBatched(Box::new(SlabModel::from_packed(&params, &packed, 0))),
            ServerConfig::default(),
        ),
    )
    .expect("bind loopback");
    let addr = http.addr();
    let http_reqs = if fast { 4 } else { 16 };
    let t_http = Instant::now();
    let mut http_tokens = 0usize;
    for i in 0..http_reqs {
        let body = format!(
            "{{\"prompt\": {:?}, \"max_new\": 16}}",
            bench_prompt(i, cfg.prompt_len)
        );
        let reply = client::post(addr, "/v1/generate", &body).expect("http generate");
        let (_, r) = client::parse_generate_reply(&reply.body).expect("parse http reply");
        http_tokens += r.tokens.len();
    }
    let http_wall = t_http.elapsed().as_secs_f64();
    let http_tps = http_tokens as f64 / http_wall.max(1e-9);
    http.shutdown().expect("http server stats");
    println!(
        "http loopback: {http_reqs} sequential requests, {http_tokens} tokens, {http_tps:.1} tok/s"
    );

    // --- concurrent streaming sessions (event loop) -------------------
    // 256 simultaneous SSE streams (32 under SLAB_BENCH_FAST) through
    // the event-driven front-end (DESIGN.md §15): far more live
    // connections than worker threads, every stream completing with
    // its terminal frame. The per-sec rates gate event-loop
    // regressions in CI.
    let conc_streams = if fast { 32 } else { 256 };
    let conc_budget = 8usize;
    let conc_workers = 16usize;
    let http = HttpServer::bind_with(
        "127.0.0.1:0",
        Server::start_with(
            Backend::NativeBatched(Box::new(SlabModel::from_packed(&params, &packed, 0))),
            ServerConfig {
                queue_cap: 512,
                sched: SchedulerConfig {
                    max_batch: 8,
                    queue_cap: 512,
                    ..Default::default()
                },
                ..Default::default()
            },
        ),
        HttpConfig {
            max_conns: 512,
            workers: conc_workers,
            ..HttpConfig::default()
        },
    )
    .expect("bind loopback");
    let addr = http.addr();
    let plen = cfg.prompt_len;
    let t_conc = Instant::now();
    let handles: Vec<_> = (0..conc_streams)
        .map(|i| {
            std::thread::spawn(move || -> usize {
                let body = format!(
                    "{{\"prompt\": {:?}, \"max_new\": {conc_budget}, \"stream\": true}}",
                    bench_prompt(i, plen)
                );
                let mut sse = client::SseStream::open(addr, &body).expect("open sse");
                assert_eq!(sse.status, 200);
                let mut tokens = 0usize;
                let mut terminal = false;
                while let Some(frame) = sse.next_frame().expect("frame") {
                    if frame.get("token").as_i64().is_some() {
                        tokens += 1;
                    } else if !frame.get("done").is_null() {
                        terminal = true;
                    }
                }
                assert!(terminal, "stream must end with a terminal frame");
                tokens
            })
        })
        .collect();
    let conc_tokens: usize = handles
        .into_iter()
        .map(|h| h.join().expect("stream thread"))
        .sum();
    let conc_wall = t_conc.elapsed().as_secs_f64();
    let conc_stats = http.shutdown().expect("concurrent http stats");
    assert_eq!(conc_stats.requests, conc_streams, "exact terminal accounting");
    let conc_tps = conc_tokens as f64 / conc_wall.max(1e-9);
    let conc_sps = conc_streams as f64 / conc_wall.max(1e-9);
    println!(
        "http concurrent: {conc_streams} simultaneous streams over {conc_workers} workers, \
         {conc_tokens} tokens, {conc_tps:.1} tok/s, {conc_sps:.1} streams/s"
    );

    // --- keep-alive reuse vs per-request connections ------------------
    // The same blocking generate, once over a single reused keep-alive
    // connection and once with a fresh connection per request: the
    // delta is pure connect/teardown overhead the reuse path saves.
    let ka_reqs = if fast { 8 } else { 64 };
    let http = HttpServer::bind(
        "127.0.0.1:0",
        Server::start_with(
            Backend::NativeBatched(Box::new(SlabModel::from_packed(&params, &packed, 0))),
            ServerConfig::default(),
        ),
    )
    .expect("bind loopback");
    let addr = http.addr();
    let ka_body = format!(
        "{{\"prompt\": {:?}, \"max_new\": 2}}",
        bench_prompt(0, cfg.prompt_len)
    );
    let t_ka = Instant::now();
    let mut conn = client::HttpConn::connect(addr).expect("connect keep-alive");
    for _ in 0..ka_reqs {
        let reply = conn
            .request("POST", "/v1/generate", Some(&ka_body))
            .expect("keep-alive generate");
        assert_eq!(reply.status, 200, "{}", reply.body);
    }
    let ka_wall = t_ka.elapsed().as_secs_f64();
    drop(conn);
    let t_os = Instant::now();
    for _ in 0..ka_reqs {
        let reply = client::post(addr, "/v1/generate", &ka_body).expect("one-shot generate");
        assert_eq!(reply.status, 200, "{}", reply.body);
    }
    let os_wall = t_os.elapsed().as_secs_f64();
    http.shutdown().expect("keep-alive http stats");
    let ka_rps = ka_reqs as f64 / ka_wall.max(1e-9);
    let os_rps = ka_reqs as f64 / os_wall.max(1e-9);
    println!(
        "http keep-alive: {ka_reqs} requests reused {ka_rps:.1} req/s vs one-shot {os_rps:.1} req/s"
    );

    // --- shared-prefix churn ------------------------------------------
    // High session churn over one common prompt: every admission after
    // the first joins the cached prefill copy-on-write (DESIGN.md §13)
    // instead of re-running prefill, so tokens/s under churn is the
    // prefix cache's end-to-end win.
    let server = Server::start_with(
        Backend::NativeBatched(Box::new(SlabModel::from_packed(&params, &packed, 0))),
        ServerConfig {
            sched: SchedulerConfig {
                max_batch: 4,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let churn_waves = if fast { 2 } else { 12 };
    let churn_budget = 4usize;
    let common = bench_prompt(0, cfg.prompt_len);
    let t_churn = Instant::now();
    let mut churn_tokens = 0usize;
    let mut churn_sessions = 0usize;
    for _ in 0..churn_waves {
        let wave: Vec<_> = (0..4)
            .map(|_| {
                server.submit(Request {
                    prompt: common.clone(),
                    max_new: churn_budget,
                    deadline: None,
                })
            })
            .collect();
        churn_sessions += wave.len();
        for s in wave {
            churn_tokens += s.collect().tokens.len();
        }
    }
    let churn_wall = t_churn.elapsed().as_secs_f64();
    let churn_stats = server.shutdown().expect("churn server stats");
    let churn_tps = churn_tokens as f64 / churn_wall.max(1e-9);
    println!(
        "shared-prefix churn: {churn_sessions} sessions, hit rate {:.3} \
         ({} hits / {} misses, {} cow splits), {churn_tps:.1} tok/s",
        churn_stats.prefix_hit_rate(),
        churn_stats.prefix_hits,
        churn_stats.prefix_misses,
        churn_stats.cow_splits
    );

    // --- self-speculative decode --------------------------------------
    // The same distinct-prompt workload through a plain scheduler and
    // a `speculate` one (DESIGN.md §14): tokens/s side by side plus
    // the served acceptance rate. The contract is lossless — the
    // speculative run must emit the exact same streams — so any
    // throughput delta is pure draft/verify scheduling.
    let spec_sessions = if fast { 4 } else { 16 };
    let spec_budget = if fast { 6 } else { 24 };
    let spec_draft_len = 4usize;
    let run_serve = |speculate: bool| -> (f64, ServeStats, Vec<Vec<i32>>) {
        let server = Server::start_with(
            Backend::NativeBatched(Box::new(SlabModel::from_packed(&params, &packed, 0))),
            ServerConfig {
                sched: SchedulerConfig {
                    max_batch: 4,
                    speculate,
                    draft_len: spec_draft_len,
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        let t0 = Instant::now();
        let sessions: Vec<_> = (0..spec_sessions)
            .map(|i| {
                server.submit(Request {
                    prompt: bench_prompt(i, cfg.prompt_len),
                    max_new: spec_budget,
                    deadline: None,
                })
            })
            .collect();
        let streams: Vec<Vec<i32>> = sessions.into_iter().map(|s| s.collect().tokens).collect();
        let wall = t0.elapsed().as_secs_f64();
        let stats = server.shutdown().expect("speculative bench server stats");
        let tokens: usize = streams.iter().map(|s| s.len()).sum();
        (tokens as f64 / wall.max(1e-9), stats, streams)
    };
    let (spec_plain_tps, _, spec_plain_streams) = run_serve(false);
    let (spec_tps, spec_stats, spec_streams) = run_serve(true);
    assert_eq!(
        spec_streams, spec_plain_streams,
        "speculative decode must be lossless"
    );
    println!(
        "speculative decode (draft_len {spec_draft_len}): plain {spec_plain_tps:.1} tok/s vs \
         speculate {spec_tps:.1} tok/s ({:.2}x), acceptance {:.3} \
         ({} accepted / {} drafted, {} rollbacks)",
        spec_tps / spec_plain_tps.max(1e-9),
        spec_stats.acceptance_rate(),
        spec_stats.spec_accepted,
        spec_stats.spec_drafted,
        spec_stats.spec_rollbacks
    );

    // --- paged capacity at fixed memory -------------------------------
    // Give the paged pool exactly the page budget a 4-session
    // contiguous pool preallocates, then count how many *real*
    // prompt-length sessions each admission path fits: distinct
    // prompts pay their prompt pages, identical prompts share them.
    let contiguous_sessions = 4usize;
    let page_size = 8usize;
    let eq_pages = contiguous_sessions * cfg.max_seq.div_ceil(page_size);
    let session_cap = 64usize;
    let mut distinct_pool = PagedKvPool::for_model(
        &model,
        session_cap,
        PagedKvConfig {
            page_size,
            n_pages: eq_pages,
            prefix_sharing: false,
        },
    );
    let mut distinct = 0usize;
    while distinct < session_cap {
        let prompt = bench_prompt(distinct, cfg.prompt_len);
        let padded = model.pad_prompt(&prompt);
        let (logits, cache) = model.prefill_session(&prompt);
        if distinct_pool
            .adopt_prefill(&padded, logits.row(0), &cache)
            .is_none()
        {
            break;
        }
        distinct += 1;
    }
    let peak_pages = distinct_pool.counters().pages_peak;
    let mut shared_pool = PagedKvPool::for_model(
        &model,
        session_cap,
        PagedKvConfig {
            page_size,
            n_pages: eq_pages,
            prefix_sharing: true,
        },
    );
    let common_padded = model.pad_prompt(&common);
    let (common_logits, common_cache) = model.prefill_session(&common);
    let mut shared = 0usize;
    if shared_pool
        .adopt_prefill(&common_padded, common_logits.row(0), &common_cache)
        .is_some()
    {
        shared = 1;
        while shared < session_cap && shared_pool.admit_shared(&common_padded).is_some() {
            shared += 1;
        }
    }
    println!(
        "fixed-memory capacity ({eq_pages} pages = {contiguous_sessions} contiguous sessions): \
         {distinct} distinct-prompt sessions, {shared} shared-prefix sessions"
    );

    let summary = Json::obj(vec![
        ("bench", Json::str("serve_batched_decode")),
        (
            "model",
            Json::obj(vec![
                ("dim", Json::from_usize(cfg.dim)),
                ("n_layers", Json::from_usize(cfg.n_layers)),
                ("ffn", Json::from_usize(cfg.ffn)),
                ("vocab", Json::from_usize(cfg.vocab)),
                ("prompt_len", Json::from_usize(cfg.prompt_len)),
            ]),
        ),
        (
            "tokens_per_sec",
            Json::obj(vec![
                ("batch_1", Json::num(tps_for(1))),
                ("batch_4", Json::num(tps_for(4))),
                ("batch_8", Json::num(tps_for(8))),
            ]),
        ),
        ("serial_8_sessions_tokens_per_sec", Json::num(serial_tps)),
        ("speedup_batch8_vs_serial8", Json::num(speedup)),
        ("ttft_ms_mean", Json::num(ttft_mean)),
        (
            "cancel_under_load",
            Json::obj(vec![
                ("long_sessions", Json::from_usize(n_long)),
                ("drain_ms", Json::num(cancel_drain_ms)),
                ("post_cancel_ttft_ms", Json::num(post_cancel_ttft_ms)),
                ("cancelled", Json::from_usize(cancel_stats.cancelled)),
            ]),
        ),
        (
            "http_loopback",
            Json::obj(vec![
                ("requests", Json::from_usize(http_reqs)),
                ("generated_tokens", Json::from_usize(http_tokens)),
                ("tokens_per_sec", Json::num(http_tps)),
            ]),
        ),
        (
            "http_concurrent",
            Json::obj(vec![
                ("streams", Json::from_usize(conc_streams)),
                ("workers", Json::from_usize(conc_workers)),
                ("generated_tokens", Json::from_usize(conc_tokens)),
                ("tokens_per_sec", Json::num(conc_tps)),
                ("streams_per_sec", Json::num(conc_sps)),
            ]),
        ),
        (
            "http_keepalive",
            Json::obj(vec![
                ("requests", Json::from_usize(ka_reqs)),
                ("keepalive_requests_per_sec", Json::num(ka_rps)),
                ("oneshot_requests_per_sec", Json::num(os_rps)),
            ]),
        ),
        (
            "prefix_cache",
            Json::obj(vec![
                ("sessions", Json::from_usize(churn_sessions)),
                ("hits", Json::from_usize(churn_stats.prefix_hits)),
                ("misses", Json::from_usize(churn_stats.prefix_misses)),
                ("hit_rate", Json::num(churn_stats.prefix_hit_rate())),
                ("cow_splits", Json::from_usize(churn_stats.cow_splits)),
                ("churn_tokens_per_sec", Json::num(churn_tps)),
            ]),
        ),
        (
            "speculative_decode",
            Json::obj(vec![
                ("sessions", Json::from_usize(spec_sessions)),
                ("draft_len", Json::from_usize(spec_draft_len)),
                ("plain_tokens_per_sec", Json::num(spec_plain_tps)),
                ("speculative_tokens_per_sec", Json::num(spec_tps)),
                ("acceptance_rate", Json::num(spec_stats.acceptance_rate())),
                ("drafted", Json::from_usize(spec_stats.spec_drafted)),
                ("accepted", Json::from_usize(spec_stats.spec_accepted)),
                ("rollbacks", Json::from_usize(spec_stats.spec_rollbacks)),
            ]),
        ),
        (
            "paged_kv",
            Json::obj(vec![
                ("page_size", Json::from_usize(page_size)),
                ("page_budget", Json::from_usize(eq_pages)),
                ("peak_pages", Json::from_usize(peak_pages)),
                (
                    "contiguous_sessions_at_same_memory",
                    Json::from_usize(contiguous_sessions),
                ),
                ("paged_sessions_at_same_memory", Json::from_usize(distinct)),
                (
                    "shared_prefix_sessions_at_same_memory",
                    Json::from_usize(shared),
                ),
            ]),
        ),
    ]);
    std::fs::write("BENCH_serve.json", summary.to_pretty()).expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json");
}
