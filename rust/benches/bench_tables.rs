//! Table regeneration harness: re-derives the paper's Table I rows
//! (and Fig. 3 series) on the `small` model and reports wall-clock per
//! pipeline stage. Requires `make artifacts` and a trained checkpoint
//! (`runs/small.slabckpt`, produced by the e2e example or
//! `slab train --model small`); skips gracefully otherwise so
//! `cargo bench` never hard-fails on a fresh clone.

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

use slab::experiments::{self, Lab};
use std::path::Path;

fn main() {
    let artifacts = Path::new("artifacts");
    let runs = Path::new("runs");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts`; skipping table benches");
        return;
    }
    let mut lab = match Lab::new(artifacts, runs) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("lab init failed: {e}; skipping");
            return;
        }
    };
    lab.task_items = 20; // bench mode: smaller suites, same shape
    if !runs.join("small.slabckpt").exists() {
        eprintln!(
            "runs/small.slabckpt missing — run `make e2e` or `slab train --model small`; skipping"
        );
        return;
    }

    let t0 = std::time::Instant::now();
    match experiments::table1(
        &lab,
        &["small".to_string()],
        &["Dense".to_string(), "US (50%)".to_string(), "2:4".to_string()],
    ) {
        Ok(t) => {
            t.print();
            eprintln!("[bench_tables] table1 subset in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => eprintln!("table1 failed: {e}"),
    }

    let t0 = std::time::Instant::now();
    match experiments::fig3(&lab, "small", 3) {
        Ok(t) => {
            t.print();
            eprintln!("[bench_tables] fig3 in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => eprintln!("fig3 failed: {e}"),
    }
}
