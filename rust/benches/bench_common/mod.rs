//! Shared bench fixtures (`bench_serve`, `bench_end_to_end`,
//! `bench_eval`): the native SLaB decomposition that feeds every
//! packed-engine bench. A bench opts in with `mod bench_common;` —
//! cargo does not auto-discover `benches/*/mod.rs` as targets, so
//! this compiles only as part of the benches that include it.

// Each bench uses a subset; unused helpers must not trip -D warnings.
#![allow(dead_code)]

use slab::model::Params;
use slab::slab::{decompose, ActStats, SlabConfig, SlabLayer};
use slab::tensor::Mat;
use slab::util::rng::Pcg64;

/// Decompose every pruned linear of `params` natively — the packed
/// engine input, without artifacts or a runtime. (Bench-sized
/// Algorithm-1 budget: 3 iterations, 6 SVD power steps.)
pub fn compress_native(params: &Params, seed: u64) -> Vec<(String, SlabLayer)> {
    let mut rng = Pcg64::seed_from_u64(seed);
    let scfg = SlabConfig {
        iters: 3,
        svd_iters: 6,
        ..Default::default()
    };
    let mut packed = Vec::new();
    for (name, (_, din)) in params.cfg.pruned.clone() {
        let w = params.mat(&name);
        let stats = ActStats::from_activations(&Mat::randn(64, din, 1.0, &mut rng));
        let d = decompose(&w, &stats, &scfg).expect("decompose");
        packed.push((name, SlabLayer::from_decomposition(&d)));
    }
    packed
}
