//! Kernel-level benches: the deployment hot path.
//!
//! Compares, at paper-relevant shapes, the per-forward cost of
//! dense GEMM vs CSR sparse vs bitpacked-binary vs the full packed
//! SLaB layer (CSR + rank-1 + bitplane) — the CPU analogue of the
//! HBM-bytes argument in DESIGN.md §9 — each in its scalar-reference,
//! cache-blocked, and ThreadPool-parallel forms, plus the fused
//! packed forward the serving engine runs and the AOT Pallas
//! `slab_linear` artifact when `artifacts/` is present.
//!
//! The ≥512-dim rows are the acceptance gate for the parallel
//! kernels: row-chunking must beat the scalar loops once the weight
//! working set leaves L2.

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

use slab::binary::BitMat;
use slab::slab::{decompose, ActStats, SlabConfig, SlabLayer};
use slab::sparse::Csr;
use slab::tensor::{matmul_bt, Mat};
use slab::util::bench::Bench;
use slab::util::pool::ThreadPool;
use slab::util::rng::Pcg64;
use std::path::Path;

fn main() {
    let mut rng = Pcg64::seed_from_u64(77);
    let pool = ThreadPool::new(0);
    let shapes = [
        (256usize, 256usize),
        (688, 256),
        (512, 512),
        (1024, 512),
    ];
    let batch = 32usize;

    for (dout, din) in shapes {
        let mut b = Bench::new(&format!("linear {dout}x{din} (batch {batch})"));
        let w = Mat::randn(dout, din, 0.02, &mut rng);
        let x = Mat::randn(batch, din, 1.0, &mut rng);
        let stats = ActStats::from_activations(&Mat::randn(256, din, 1.0, &mut rng));
        let cfg = SlabConfig {
            iters: 5,
            ..Default::default()
        };
        let d = decompose(&w, &stats, &cfg).expect("decompose");
        let layer = SlabLayer::from_decomposition(&d);
        let csr = Csr::from_dense(&d.w_s);
        let bits = BitMat::from_sign_of(&d.w_b);
        let flops = 2.0 * batch as f64 * dout as f64 * din as f64;

        b.run_throughput("dense matmul_bt", flops, "flop", || matmul_bt(&x, &w));
        b.run_throughput(
            &format!("csr spmm scalar ({} nnz, {:.0}%)", csr.nnz(), 100.0 * csr.density()),
            flops,
            "flop",
            || csr.spmm_bt(&x),
        );
        b.run_throughput("csr spmm blocked", flops, "flop", || csr.spmm_bt_blocked(&x));
        b.run_throughput(
            &format!("csr spmm parallel x{}", pool.size()),
            flops,
            "flop",
            || csr.spmm_bt_par(&x, &pool),
        );
        b.run_throughput("bitpacked ±1 scalar", flops, "flop", || bits.matmul_bt(&x));
        b.run_throughput("bitpacked ±1 blocked", flops, "flop", || {
            bits.matmul_bt_blocked(&x)
        });
        b.run_throughput(
            &format!("bitpacked ±1 parallel x{}", pool.size()),
            flops,
            "flop",
            || bits.matmul_bt_par(&x, &pool),
        );
        b.run_throughput("slab packed forward (scalar)", flops, "flop", || {
            layer.forward(&x)
        });
        b.run_throughput("slab fused forward", flops, "flop", || {
            layer.forward_fused(&x, None)
        });
        b.run_throughput(
            &format!("slab fused parallel x{}", pool.size()),
            flops,
            "flop",
            || layer.forward_fused(&x, Some(&pool)),
        );
        println!(
            "  [bytes] dense f32 {} | slab packed {} ({:.2}x smaller)",
            dout * din * 4,
            layer.nbytes_deploy(),
            (dout * din * 4) as f64 / layer.nbytes_deploy() as f64
        );
        b.finish();
    }

    // Decode-shaped batch: batch 1 is where row-chunking (not batch
    // parallelism) has to carry the speedup.
    {
        let (dout, din) = (1024usize, 512usize);
        let mut b = Bench::new(&format!("decode linear {dout}x{din} (batch 1)"));
        let w = Mat::randn(dout, din, 0.02, &mut rng);
        let x = Mat::randn(1, din, 1.0, &mut rng);
        let stats = ActStats::from_activations(&Mat::randn(256, din, 1.0, &mut rng));
        let d = decompose(&w, &stats, &SlabConfig { iters: 5, ..Default::default() })
            .expect("decompose");
        let layer = SlabLayer::from_decomposition(&d);
        let flops = 2.0 * dout as f64 * din as f64;
        b.run_throughput("dense matmul_bt", flops, "flop", || matmul_bt(&x, &w));
        b.run_throughput("slab fused forward", flops, "flop", || {
            layer.forward_fused(&x, None)
        });
        b.run_throughput(
            &format!("slab fused parallel x{}", pool.size()),
            flops,
            "flop",
            || layer.forward_fused(&x, Some(&pool)),
        );
        b.finish();
    }

    // AOT Pallas slab_linear artifact (needs `make artifacts`).
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        if let Ok(rt) = slab::runtime::Runtime::new(dir) {
            let mut b = Bench::new("AOT slab_linear artifact (PJRT CPU)");
            let kb = rt.manifest.kernel_bench_batch;
            for (dout, din) in [(128usize, 128usize), (344, 128)] {
                let name = format!("slab_linear_{dout}x{din}");
                if rt.manifest.artifact(&name).is_none() {
                    continue;
                }
                let w = Mat::randn(dout, din, 0.02, &mut rng);
                let x = Mat::randn(kb, din, 1.0, &mut rng);
                let u = vec![0.1f32; dout];
                let v = vec![0.1f32; din];
                let bm = Mat::randn(dout, din, 1.0, &mut rng).sign_pm1();
                let inputs = vec![
                    slab::runtime::lit_mat(&x),
                    slab::runtime::lit_mat(&w),
                    slab::runtime::lit_f32(&u, &[dout]),
                    slab::runtime::lit_f32(&v, &[din]),
                    slab::runtime::lit_mat(&bm),
                ];
                let flops = 2.0 * kb as f64 * dout as f64 * din as f64;
                b.run_throughput(&name, flops, "flop", || {
                    rt.execute(&name, &inputs).expect("exec")
                });
            }
            b.finish();
        }
    } else {
        eprintln!("(artifacts/ missing — skipping AOT kernel benches; run `make artifacts`)");
    }
}
