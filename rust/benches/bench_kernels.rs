//! Kernel-level benches: the deployment hot path.
//!
//! Compares, at paper-relevant shapes, the per-forward cost of
//! dense GEMM vs CSR sparse vs bitpacked-binary vs the full packed
//! SLaB layer (CSR + rank-1 + bitplane) — the CPU analogue of the
//! HBM-bytes argument in DESIGN.md §9 — each in its scalar-reference,
//! cache-blocked, ThreadPool-parallel, and word/unrolled `fast`
//! forms, plus the fused packed forward the serving engine runs and
//! the AOT Pallas `slab_linear` artifact when `artifacts/` is
//! present.
//!
//! Beyond the printed tables, the decode-shaped (batch-1) and 2:4
//! semi-structured groups are written to `BENCH_kernels.json` as
//! roofline rows: tokens/s, bytes moved per token, achieved GB/s,
//! and the fraction of a measured STREAM-style bandwidth ceiling
//! (`peak_frac`). CI's bench-smoke job greps these keys and the
//! perf-gate job diffs the `*_per_sec` / `*_gbps` leaves against the
//! previous main-branch run via `rust/ci/bench_compare.rs`.
//!
//! The ≥512-dim rows are the acceptance gate for the parallel
//! kernels: row-chunking must beat the scalar loops once the weight
//! working set leaves L2. The batch-1 group is the acceptance gate
//! for PR 7's fused decode epilogue: the `forward_decode` rows must
//! beat the scalar-order fused-parallel baseline.

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

use slab::binary::BitMat;
use slab::slab::{decompose, ActStats, SlabConfig, SlabLayer};
use slab::sparse::{Csr, NmPacked, PATTERN_2_4};
use slab::tensor::{matmul_bt, Mat};
use slab::util::bench::{black_box, Bench, Stats};
use slab::util::json::Json;
use slab::util::kernel::KernelMode;
use slab::util::pool::ThreadPool;
use slab::util::rng::Pcg64;
use std::path::Path;
use std::time::Instant;

/// STREAM-style bandwidth ceiling: best-of-N copy and triad passes
/// over buffers sized well past L2 so the measurement is DRAM-bound,
/// not cache-bound. Returns (copy GB/s, triad GB/s). The triad
/// number is the roofline ceiling the kernel rows are scored
/// against: like them it mixes reads, writes, and FLOPs.
fn measure_stream(smoke: bool) -> (f64, f64) {
    let n: usize = if smoke { 1 << 20 } else { 4 << 20 };
    let reps = if smoke { 3 } else { 7 };
    let a = vec![1.0f32; n];
    let b = vec![2.0f32; n];
    let mut c = vec![0.0f32; n];
    let mut best_copy = 0.0f64;
    let mut best_triad = 0.0f64;
    for _ in 0..reps {
        let t = Instant::now();
        c.copy_from_slice(&a);
        black_box(&c);
        let dt = t.elapsed().as_secs_f64();
        // copy moves 2 arrays (read a, write c) of n f32 each.
        best_copy = best_copy.max(2.0 * n as f64 * 4.0 / dt / 1e9);

        let t = Instant::now();
        for i in 0..n {
            c[i] = a[i] + 3.0f32 * b[i];
        }
        black_box(&c);
        let dt = t.elapsed().as_secs_f64();
        // triad moves 3 arrays (read a, read b, write c).
        best_triad = best_triad.max(3.0 * n as f64 * 4.0 / dt / 1e9);
    }
    (best_copy, best_triad)
}

/// One roofline row for the JSON summary. `bytes` is the weight +
/// activation traffic per iteration (one decode token here), so
/// `achieved_gbps / ceiling` says how close the kernel runs to the
/// measured memory-bandwidth roof — decode matvecs have arithmetic
/// intensity well under 1 FLOP/byte, so bandwidth IS the roof.
fn roofline_row(name: &str, s: &Stats, bytes: f64, flops: f64, ceiling_gbps: f64) -> Json {
    let per_sec = s.throughput(1.0);
    let gbps = bytes * per_sec / 1e9;
    Json::obj(vec![
        ("name", Json::str(name)),
        ("mean_ns", Json::num(s.mean_ns)),
        ("tokens_per_sec", Json::num(per_sec)),
        ("gflops_effective", Json::num(flops * per_sec / 1e9)),
        ("bytes_per_token", Json::num(bytes)),
        ("achieved_gbps", Json::num(gbps)),
        ("peak_frac", Json::num(if ceiling_gbps > 0.0 { gbps / ceiling_gbps } else { 0.0 })),
    ])
}

fn main() {
    let mut rng = Pcg64::seed_from_u64(77);
    let pool = ThreadPool::new(0);
    let smoke = std::env::var("SLAB_BENCH_FAST").as_deref() == Ok("1");

    let (copy_gbps, triad_gbps) = measure_stream(smoke);
    println!(
        "STREAM ceiling: copy {copy_gbps:.2} GB/s | triad {triad_gbps:.2} GB/s \
         ({} f32/array)",
        if smoke { 1usize << 20 } else { 4usize << 20 }
    );

    let shapes = [
        (256usize, 256usize),
        (688, 256),
        (512, 512),
        (1024, 512),
    ];
    let batch = 32usize;
    let mut shape_rows: Vec<Json> = Vec::new();

    for (dout, din) in shapes {
        let mut b = Bench::new(&format!("linear {dout}x{din} (batch {batch})"));
        let w = Mat::randn(dout, din, 0.02, &mut rng);
        let x = Mat::randn(batch, din, 1.0, &mut rng);
        let stats = ActStats::from_activations(&Mat::randn(256, din, 1.0, &mut rng));
        let cfg = SlabConfig {
            iters: 5,
            ..Default::default()
        };
        let d = decompose(&w, &stats, &cfg).expect("decompose");
        let layer = SlabLayer::from_decomposition(&d);
        let csr = Csr::from_dense(&d.w_s);
        let bits = BitMat::from_sign_of(&d.w_b);
        let flops = 2.0 * batch as f64 * dout as f64 * din as f64;
        let gfl = |s: &Stats| s.throughput(flops) / 1e9;

        let s_dense = b.run_throughput("dense matmul_bt", flops, "flop", || matmul_bt(&x, &w));
        b.run_throughput(
            &format!("csr spmm scalar ({} nnz, {:.0}%)", csr.nnz(), 100.0 * csr.density()),
            flops,
            "flop",
            || csr.spmm_bt(&x),
        );
        b.run_throughput("csr spmm blocked", flops, "flop", || csr.spmm_bt_blocked(&x));
        let s_csr_par = b.run_throughput(
            &format!("csr spmm parallel x{}", pool.size()),
            flops,
            "flop",
            || csr.spmm_bt_par(&x, &pool),
        );
        let s_csr_fast = b.run_throughput(
            &format!("csr spmm fast parallel x{}", pool.size()),
            flops,
            "flop",
            || csr.spmm_bt_fast(&x, Some(&pool)),
        );
        b.run_throughput("bitpacked ±1 scalar", flops, "flop", || bits.matmul_bt(&x));
        b.run_throughput("bitpacked ±1 blocked", flops, "flop", || {
            bits.matmul_bt_blocked(&x)
        });
        let s_bit_par = b.run_throughput(
            &format!("bitpacked ±1 parallel x{}", pool.size()),
            flops,
            "flop",
            || bits.matmul_bt_par(&x, &pool),
        );
        let s_bit_fast = b.run_throughput(
            &format!("bitpacked ±1 word-fast parallel x{}", pool.size()),
            flops,
            "flop",
            || bits.matmul_bt_fast(&x, Some(&pool)),
        );
        b.run_throughput("slab packed forward (scalar)", flops, "flop", || {
            layer.forward(&x)
        });
        b.run_throughput("slab fused forward", flops, "flop", || {
            layer.forward_fused(&x, None)
        });
        let s_fused_par = b.run_throughput(
            &format!("slab fused parallel x{}", pool.size()),
            flops,
            "flop",
            || layer.forward_fused(&x, Some(&pool)),
        );
        println!(
            "  [bytes] dense f32 {} | slab packed {} ({:.2}x smaller)",
            dout * din * 4,
            layer.nbytes_deploy(),
            (dout * din * 4) as f64 / layer.nbytes_deploy() as f64
        );
        b.finish();

        shape_rows.push(Json::obj(vec![
            ("dout", Json::from_usize(dout)),
            ("din", Json::from_usize(din)),
            ("batch", Json::from_usize(batch)),
            (
                "gflops",
                Json::obj(vec![
                    ("dense", Json::num(gfl(&s_dense))),
                    ("csr_parallel", Json::num(gfl(&s_csr_par))),
                    ("csr_fast_parallel", Json::num(gfl(&s_csr_fast))),
                    ("bitpacked_parallel", Json::num(gfl(&s_bit_par))),
                    ("bitpacked_fast_parallel", Json::num(gfl(&s_bit_fast))),
                    ("slab_fused_parallel", Json::num(gfl(&s_fused_par))),
                ]),
            ),
        ]));
    }

    // Decode-shaped batch: batch 1 is where row-chunking (not batch
    // parallelism) has to carry the speedup, and where PR 7's fused
    // epilogue (one activation pass per token) earns its keep. The
    // baseline for the acceptance gate is the scalar-order fused
    // parallel path the serving engine ran before `forward_decode`
    // existed.
    let decode_summary;
    {
        let (dout, din) = (1024usize, 512usize);
        let mut b = Bench::new(&format!("decode linear {dout}x{din} (batch 1)"));
        let w = Mat::randn(dout, din, 0.02, &mut rng);
        let x = Mat::randn(1, din, 1.0, &mut rng);
        let stats = ActStats::from_activations(&Mat::randn(256, din, 1.0, &mut rng));
        let d = decompose(&w, &stats, &SlabConfig { iters: 5, ..Default::default() })
            .expect("decompose");
        let layer = SlabLayer::from_decomposition(&d);
        let flops = 2.0 * dout as f64 * din as f64;
        // Per-token traffic: the packed weights stream once, plus the
        // activation read, the rank-r scaled copies, and the output
        // write. (Rank-r scratch is din*rank floats, written + read.)
        let slab_bytes = layer.nbytes_deploy() as f64
            + (din + 3 * din * layer.rank() + dout) as f64 * 4.0;
        let dense_bytes = (dout * din + din + dout) as f64 * 4.0;

        let s_dense = b.run_throughput("dense matmul_bt", flops, "flop", || matmul_bt(&x, &w));
        b.run_throughput("slab fused forward", flops, "flop", || {
            layer.forward_fused(&x, None)
        });
        let s_base = b.run_throughput(
            &format!("slab fused parallel x{} (baseline)", pool.size()),
            flops,
            "flop",
            || layer.forward_fused(&x, Some(&pool)),
        );
        let s_dec_exact = b.run_throughput("fused decode exact", flops, "flop", || {
            layer.forward_decode(&x, None, KernelMode::Exact)
        });
        let s_dec_exact_par = b.run_throughput(
            &format!("fused decode exact parallel x{}", pool.size()),
            flops,
            "flop",
            || layer.forward_decode(&x, Some(&pool), KernelMode::Exact),
        );
        let s_dec_fast = b.run_throughput("fused decode fast", flops, "flop", || {
            layer.forward_decode(&x, None, KernelMode::Fast)
        });
        let s_dec_fast_par = b.run_throughput(
            &format!("fused decode fast parallel x{}", pool.size()),
            flops,
            "flop",
            || layer.forward_decode(&x, Some(&pool), KernelMode::Fast),
        );
        b.finish();

        // Best fused-decode config (serving picks per-shape): lowest
        // mean over {exact, fast} x {serial, parallel}.
        let best = [&s_dec_exact, &s_dec_exact_par, &s_dec_fast, &s_dec_fast_par]
            .iter()
            .map(|s| s.mean_ns)
            .fold(f64::INFINITY, f64::min);
        let speedup = s_base.mean_ns / best;
        println!(
            "  fused decode speedup vs scalar-order parallel baseline: {speedup:.2}x \
             ({:.0} -> {:.0} ns/token)",
            s_base.mean_ns, best
        );

        decode_summary = Json::obj(vec![
            ("dout", Json::from_usize(dout)),
            ("din", Json::from_usize(din)),
            ("rank", Json::from_usize(layer.rank())),
            ("weight_bytes_packed", Json::from_usize(layer.nbytes_deploy())),
            (
                "rows",
                Json::arr(vec![
                    roofline_row("dense matmul_bt", &s_dense, dense_bytes, flops, triad_gbps),
                    roofline_row(
                        "slab fused parallel (baseline)",
                        &s_base,
                        slab_bytes,
                        flops,
                        triad_gbps,
                    ),
                    roofline_row("fused decode exact", &s_dec_exact, slab_bytes, flops, triad_gbps),
                    roofline_row(
                        "fused decode exact parallel",
                        &s_dec_exact_par,
                        slab_bytes,
                        flops,
                        triad_gbps,
                    ),
                    roofline_row("fused decode fast", &s_dec_fast, slab_bytes, flops, triad_gbps),
                    roofline_row(
                        "fused decode fast parallel",
                        &s_dec_fast_par,
                        slab_bytes,
                        flops,
                        triad_gbps,
                    ),
                ]),
            ),
            ("baseline_tokens_per_sec", Json::num(s_base.throughput(1.0))),
            ("best_fused_decode_tokens_per_sec", Json::num(1e9 / best)),
            ("fused_decode_speedup_vs_baseline", Json::num(speedup)),
        ]);
    }

    // 2:4 semi-structured group: the dedicated `row_dot_24` kernel
    // (compress `--semi` / `--pattern 2:4`) vs the generic packed
    // matvec and a CSR holding the same matrix.
    let semi_summary;
    {
        let (dout, din) = (1024usize, 512usize);
        let mut b = Bench::new(&format!("semi 2:4 {dout}x{din} (batch 1)"));
        let w = Mat::randn(dout, din, 0.02, &mut rng);
        let mask = PATTERN_2_4.mask_from_scores(&w.abs());
        let w24 = w.zip(&mask, |a, m| a * m);
        let packed = NmPacked::pack(PATTERN_2_4, &w24).expect("pack 2:4");
        let csr = Csr::from_dense(&w24);
        let x = Mat::randn(1, din, 1.0, &mut rng);
        let flops = 2.0 * dout as f64 * din as f64;
        let act_bytes = (din + dout) as f64 * 4.0;
        let packed_bytes = packed.nbytes() as f64 + act_bytes;
        let csr_bytes = (csr.nnz() * 8 + (dout + 1) * 4) as f64 + act_bytes;

        let s_csr = b.run_throughput("csr spmm (same matrix)", flops, "flop", || {
            csr.spmm_bt(&x)
        });
        let s_gen = b.run_throughput("nm packed generic", flops, "flop", || packed.spmm_bt(&x));
        let s_24 = b.run_throughput("nm 2:4 dedicated exact", flops, "flop", || {
            packed.spmm_bt_24(&x, false)
        });
        let s_24f = b.run_throughput("nm 2:4 dedicated fast", flops, "flop", || {
            packed.spmm_bt_24(&x, true)
        });
        b.finish();

        semi_summary = Json::obj(vec![
            ("pattern", Json::str(PATTERN_2_4.name())),
            ("dout", Json::from_usize(dout)),
            ("din", Json::from_usize(din)),
            ("packed_bytes", Json::from_usize(packed.nbytes())),
            (
                "rows",
                Json::arr(vec![
                    roofline_row("csr same matrix", &s_csr, csr_bytes, flops, triad_gbps),
                    roofline_row("nm packed generic", &s_gen, packed_bytes, flops, triad_gbps),
                    roofline_row("nm 2:4 dedicated exact", &s_24, packed_bytes, flops, triad_gbps),
                    roofline_row("nm 2:4 dedicated fast", &s_24f, packed_bytes, flops, triad_gbps),
                ]),
            ),
            (
                "dedicated_speedup_vs_generic",
                Json::num(s_gen.mean_ns / s_24f.mean_ns.min(s_24.mean_ns)),
            ),
        ]);
    }

    let summary = Json::obj(vec![
        ("bench", Json::str("kernels")),
        ("mode", Json::str(if smoke { "smoke" } else { "full" })),
        ("threads", Json::from_usize(pool.size())),
        (
            "stream",
            Json::obj(vec![
                // Deliberately not *_gbps keys: the ceiling tracks
                // the runner's memory system, not this repo's code,
                // so the perf-gate must not pin it.
                ("copy_ceiling_gb_s", Json::num(copy_gbps)),
                ("triad_ceiling_gb_s", Json::num(triad_gbps)),
            ]),
        ),
        ("shapes", Json::arr(shape_rows)),
        ("decode", decode_summary),
        ("semi", semi_summary),
    ]);
    std::fs::write("BENCH_kernels.json", summary.to_pretty()).expect("write BENCH_kernels.json");
    println!("wrote BENCH_kernels.json");

    // AOT Pallas slab_linear artifact (needs `make artifacts`).
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        if let Ok(rt) = slab::runtime::Runtime::new(dir) {
            let mut b = Bench::new("AOT slab_linear artifact (PJRT CPU)");
            let kb = rt.manifest.kernel_bench_batch;
            for (dout, din) in [(128usize, 128usize), (344, 128)] {
                let name = format!("slab_linear_{dout}x{din}");
                if rt.manifest.artifact(&name).is_none() {
                    continue;
                }
                let w = Mat::randn(dout, din, 0.02, &mut rng);
                let x = Mat::randn(kb, din, 1.0, &mut rng);
                let u = vec![0.1f32; dout];
                let v = vec![0.1f32; din];
                let bm = Mat::randn(dout, din, 1.0, &mut rng).sign_pm1();
                let inputs = vec![
                    slab::runtime::lit_mat(&x),
                    slab::runtime::lit_mat(&w),
                    slab::runtime::lit_f32(&u, &[dout]),
                    slab::runtime::lit_f32(&v, &[din]),
                    slab::runtime::lit_mat(&bm),
                ];
                let flops = 2.0 * kb as f64 * dout as f64 * din as f64;
                b.run_throughput(&name, flops, "flop", || {
                    rt.execute(&name, &inputs).expect("exec")
                });
            }
            b.finish();
        }
    } else {
        eprintln!("(artifacts/ missing — skipping AOT kernel benches; run `make artifacts`)");
    }
}
