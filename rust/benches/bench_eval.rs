//! Evaluation-throughput bench — the native batched-NLL harness over
//! the packed engine, serial vs row-parallel (`ThreadPool::scoped_map`
//! fan-out, bit-identical outputs), with the dense engine as a
//! reference row and — when `artifacts/` exists — the XLA
//! `eval_nll_{cfg}` path on the same rows as the cross-engine
//! comparison.
//!
//! Besides the human-readable table, writes a machine-readable summary
//! to `BENCH_eval.json` (CI's bench-smoke job uploads it alongside
//! `BENCH_serve.json` / `BENCH_decompose.json`), so eval-throughput
//! regressions are diffable across runs. `SLAB_BENCH_FAST=1` shrinks
//! everything to a smoke run.

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

mod bench_common;

use bench_common::compress_native;
use slab::data::{build_corpus, Grammar, TokenSet};
use slab::eval::native::{batched_nll, EvalOptions};
use slab::model::{Params, SlabModel};
use slab::runtime::ModelCfg;
use slab::util::bench::Bench;
use slab::util::json::Json;
use std::path::Path;

fn main() {
    let fast = std::env::var("SLAB_BENCH_FAST").as_deref() == Ok("1");
    // Big enough that the weight pass dominates per-row overhead,
    // small enough that a SLAB_BENCH_FAST smoke run stays in seconds.
    let cfg = ModelCfg::llama("bench-eval", 128, 64, 2, 4, 128, 48, 8);
    let params = Params::init(&cfg, 9);
    let packed = compress_native(&params, 10);
    let model = SlabModel::from_packed(&params, &packed, 1);
    let n_rows = if fast { 8usize } else { 32 };
    let rows = TokenSet::synthetic(n_rows, cfg.max_seq, cfg.vocab).to_rows();
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "bench-eval model: dim {}, {} layers, {} packed linears, {} rows × {} tokens",
        cfg.dim,
        cfg.n_layers,
        model.packed_linear_count(),
        n_rows,
        cfg.max_seq
    );

    let mut b = Bench::new("native eval NLL (packed engine)");
    let serial = b.run_throughput("batched_nll serial", n_rows as f64, "row", || {
        batched_nll(&model, &rows, EvalOptions { batch: 8, threads: 1 })
    });
    let par = b.run_throughput(
        &format!("batched_nll parallel x{threads}"),
        n_rows as f64,
        "row",
        || batched_nll(&model, &rows, EvalOptions { batch: 8, threads: 0 }),
    );
    let dense_model = SlabModel::from_dense(&params, 1);
    let dense = b.run_throughput("batched_nll serial (dense engine)", n_rows as f64, "row", || {
        batched_nll(&dense_model, &rows, EvalOptions { batch: 8, threads: 1 })
    });
    b.finish();
    let serial_rps = serial.throughput(n_rows as f64);
    let par_rps = par.throughput(n_rows as f64);
    println!(
        "parallel x{threads} vs serial: {:.2}x rows/s",
        par_rps / serial_rps.max(1e-9)
    );

    // Cross-engine comparison on the "small" config — the same rows
    // through the XLA eval_nll artifact vs the native harness.
    // Artifact-gated: skipped (with a note) on a fresh clone.
    let mut xla_json = Json::Null;
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        if let Ok(rt) = slab::runtime::Runtime::new(dir) {
            if let Some(small) = rt.manifest.config("small").cloned() {
                let sparams = Params::init(&small, 11);
                let smodel = SlabModel::from_dense(&sparams, 1);
                let g = Grammar::standard();
                let corpus = build_corpus(&g, 21, 1, n_rows, 1, small.max_seq);
                let srows = corpus.valid.to_rows();
                let dev =
                    slab::eval::ParamsOnDevice::upload(&rt, &sparams).expect("params upload");
                let width = small.max_seq + 1;
                let mut bx = Bench::new("cross-engine eval NLL (small config)");
                let x = bx.run_throughput("xla eval_nll", n_rows as f64, "row", || {
                    slab::eval::nll_rows(&rt, &small.name, &dev, &srows, width).expect("xla nll")
                });
                let ns = bx.run_throughput("native serial (same rows)", n_rows as f64, "row", || {
                    batched_nll(
                        &smodel,
                        &srows,
                        EvalOptions { batch: rt.manifest.eval_batch, threads: 1 },
                    )
                });
                let np = bx.run_throughput(
                    &format!("native parallel x{threads} (same rows)"),
                    n_rows as f64,
                    "row",
                    || {
                        batched_nll(
                            &smodel,
                            &srows,
                            EvalOptions { batch: rt.manifest.eval_batch, threads: 0 },
                        )
                    },
                );
                bx.finish();
                xla_json = Json::obj(vec![
                    ("config", Json::str("small")),
                    ("xla_rows_per_sec", Json::num(x.throughput(n_rows as f64))),
                    ("native_serial_rows_per_sec", Json::num(ns.throughput(n_rows as f64))),
                    ("native_parallel_rows_per_sec", Json::num(np.throughput(n_rows as f64))),
                ]);
            }
        }
    } else {
        eprintln!("(artifacts/ missing — skipping the XLA eval bench rows)");
    }

    let summary = Json::obj(vec![
        ("bench", Json::str("eval_nll")),
        (
            "model",
            Json::obj(vec![
                ("dim", Json::from_usize(cfg.dim)),
                ("n_layers", Json::from_usize(cfg.n_layers)),
                ("ffn", Json::from_usize(cfg.ffn)),
                ("vocab", Json::from_usize(cfg.vocab)),
                ("max_seq", Json::from_usize(cfg.max_seq)),
                ("rows", Json::from_usize(n_rows)),
            ]),
        ),
        (
            "rows_per_sec",
            Json::obj(vec![
                ("native_serial", Json::num(serial_rps)),
                ("native_parallel", Json::num(par_rps)),
                ("native_dense_serial", Json::num(dense.throughput(n_rows as f64))),
            ]),
        ),
        ("threads_parallel", Json::from_usize(threads)),
        ("speedup_parallel_vs_serial", Json::num(par_rps / serial_rps.max(1e-9))),
        ("xla", xla_json),
    ]);
    std::fs::write("BENCH_eval.json", summary.to_pretty()).expect("write BENCH_eval.json");
    println!("wrote BENCH_eval.json");
}
