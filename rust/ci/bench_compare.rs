//! Perf-gate comparer for CI: diff two bench summary JSON files and
//! fail on throughput regressions.
//!
//! Usage: `bench_compare <baseline.json> <current.json> [tolerance]`
//!
//! Built standalone (`rustc -O rust/ci/bench_compare.rs`) so the
//! perf-gate job needs no workspace build. Dependency-free: carries
//! its own minimal JSON reader rather than linking the library crate
//! it is gating.
//!
//! Policy (mirrors DESIGN.md §9 / ci.yml perf-gate):
//! - Pinned rows are the numeric leaves whose path contains
//!   `per_sec` or `gbps` — throughput-style, higher is better.
//!   Latency (`*_ns`), ratios (`peak_frac`, `speedup*`), and the
//!   STREAM ceilings (`*_gb_s`, runner property, not repo code) are
//!   deliberately NOT pinned.
//! - A pinned row regresses when `current < baseline * (1 - tol)`;
//!   tol defaults to 0.15. Any regression → exit 1.
//! - Baseline file missing or unreadable → `SKIP`, exit 0 (first
//!   run on a branch, or main has no artifact yet).
//! - Pinned row present in baseline but absent in current → warning
//!   only: bench-smoke's greps pin the names that must exist, so a
//!   legitimate rename must not brick the gate.

use std::collections::BTreeMap;

// ---------------------------------------------------------------
// Minimal JSON reader: only what bench summaries need (objects,
// arrays, strings, f64 numbers, true/false/null). Numbers keep f64;
// everything else is structure.
// ---------------------------------------------------------------

enum Json {
    Num(f64),
    Str,
    Bool,
    Null,
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn new(s: &'a str) -> Parser<'a> {
        Parser { b: s.as_bytes(), i: 0 }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8, String> {
        self.ws();
        self.b.get(self.i).copied().ok_or_else(|| "unexpected end of input".to_string())
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => {
                self.string()?;
                Ok(Json::Str)
            }
            b't' => self.lit("true").map(|_| Json::Bool),
            b'f' => self.lit("false").map(|_| Json::Bool),
            b'n' => self.lit("null").map(|_| Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str) -> Result<(), String> {
        self.ws();
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(())
        } else {
            Err(format!("expected '{word}' at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut pairs = Vec::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            let key = self.string()?;
            self.eat(b':')?;
            pairs.push((key, self.value()?));
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                c => return Err(format!("expected ',' or '}}', got '{}'", c as char)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => return Err(format!("expected ',' or ']', got '{}'", c as char)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        while self.i < self.b.len() {
            let c = self.b[self.i];
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' | b'f' => out.push(' '),
                        b'u' => {
                            // Bench summaries are ASCII; keep a
                            // placeholder rather than decoding
                            // surrogate pairs.
                            self.i = (self.i + 4).min(self.b.len());
                            out.push('?');
                        }
                        _ => return Err(format!("bad escape '\\{}'", e as char)),
                    }
                }
                _ => out.push(c as char),
            }
        }
        Err("unterminated string".to_string())
    }

    fn number(&mut self) -> Result<Json, String> {
        self.ws();
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{s}' at byte {start}"))
    }
}

fn parse(s: &str) -> Result<Json, String> {
    let mut p = Parser::new(s);
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

// ---------------------------------------------------------------
// Flatten numeric leaves to path -> value. Array elements that are
// objects carrying numeric "dout"/"din" fields (per-shape rows) are
// keyed by those dims so adding a shape doesn't shift every later
// row's identity; other elements fall back to their index.
// ---------------------------------------------------------------

fn flatten(j: &Json, prefix: &str, out: &mut BTreeMap<String, f64>) {
    match j {
        Json::Num(v) => {
            out.insert(prefix.to_string(), *v);
        }
        Json::Obj(pairs) => {
            for (k, v) in pairs {
                let p = if prefix.is_empty() {
                    k.clone()
                } else {
                    format!("{prefix}/{k}")
                };
                flatten(v, &p, out);
            }
        }
        Json::Arr(items) => {
            for (idx, v) in items.iter().enumerate() {
                let label = row_label(v).unwrap_or_else(|| idx.to_string());
                flatten(v, &format!("{prefix}/{label}"), out);
            }
        }
        _ => {}
    }
}

fn row_label(j: &Json) -> Option<String> {
    if let Json::Obj(pairs) = j {
        let mut dout = None;
        let mut din = None;
        for (k, v) in pairs {
            if let Json::Num(n) = v {
                if k == "dout" {
                    dout = Some(*n);
                }
                if k == "din" {
                    din = Some(*n);
                }
            }
        }
        if let (Some(a), Some(b)) = (dout, din) {
            return Some(format!("{a}x{b}"));
        }
    }
    None
}

fn pinned(path: &str) -> bool {
    path.contains("per_sec") || path.contains("gbps")
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    if args.len() < 3 {
        eprintln!("usage: bench_compare <baseline.json> <current.json> [tolerance]");
        std::process::exit(2);
    }
    let tol: f64 = args.get(3).map(|s| s.parse().expect("bad tolerance")).unwrap_or(0.15);

    let baseline_src = match std::fs::read_to_string(&args[1]) {
        Ok(s) => s,
        Err(e) => {
            println!("SKIP: no baseline at {} ({e}) — nothing to gate against", args[1]);
            std::process::exit(0);
        }
    };
    let current_src = match std::fs::read_to_string(&args[2]) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("FAIL: current summary {} unreadable: {e}", args[2]);
            std::process::exit(1);
        }
    };
    let baseline = match parse(&baseline_src) {
        Ok(j) => j,
        Err(e) => {
            // A corrupt baseline artifact must not block every PR.
            println!("SKIP: baseline {} does not parse ({e})", args[1]);
            std::process::exit(0);
        }
    };
    let current = match parse(&current_src) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("FAIL: current {} does not parse: {e}", args[2]);
            std::process::exit(1);
        }
    };

    let mut old_rows = BTreeMap::new();
    let mut new_rows = BTreeMap::new();
    flatten(&baseline, "", &mut old_rows);
    flatten(&current, "", &mut new_rows);

    let mut regressions = 0usize;
    let mut checked = 0usize;
    println!("{:-<88}", "");
    println!("{:<56} {:>12} {:>12} {:>6}", "pinned row", "baseline", "current", "delta");
    println!("{:-<88}", "");
    for (path, old) in old_rows.iter().filter(|(p, _)| pinned(p)) {
        match new_rows.get(path) {
            None => {
                println!("{path:<56} {old:>12.3} {:>12} {:>6}", "-", "GONE");
                eprintln!("warning: pinned row '{path}' missing from current run (renamed?)");
            }
            Some(new) => {
                checked += 1;
                let delta = if *old > 0.0 { new / old - 1.0 } else { 0.0 };
                let bad = *old > 0.0 && *new < old * (1.0 - tol);
                println!(
                    "{path:<56} {old:>12.3} {new:>12.3} {:>+5.1}%{}",
                    100.0 * delta,
                    if bad { "  << REGRESSION" } else { "" }
                );
                if bad {
                    regressions += 1;
                }
            }
        }
    }
    println!("{:-<88}", "");
    println!(
        "{checked} pinned rows checked, {regressions} regressed more than {:.0}%",
        tol * 100.0
    );
    if regressions > 0 {
        std::process::exit(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn leaves(src: &str) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        flatten(&parse(src).expect("parse"), "", &mut out);
        out
    }

    #[test]
    fn flattens_nested_numeric_leaves() {
        let m = leaves(r#"{"a": {"b_per_sec": 10.5, "c": [1, 2]}, "d": "x", "e": null}"#);
        assert_eq!(m.get("a/b_per_sec"), Some(&10.5));
        assert_eq!(m.get("a/c/0"), Some(&1.0));
        assert_eq!(m.get("a/c/1"), Some(&2.0));
        assert!(!m.contains_key("d"));
    }

    #[test]
    fn pinning_selects_throughput_rows_only() {
        assert!(pinned("decode/rows/2/tokens_per_sec"));
        assert!(pinned("decode/rows/2/achieved_gbps"));
        assert!(!pinned("decode/rows/2/peak_frac"));
        assert!(!pinned("decode/rows/2/mean_ns"));
        assert!(!pinned("stream/triad_ceiling_gb_s"));
        // Speculative-decode rows: both throughput leaves are gated;
        // the acceptance rate is a workload property, not a
        // higher-is-faster number, so it stays unpinned.
        assert!(pinned("speculative_decode/plain_tokens_per_sec"));
        assert!(pinned("speculative_decode/speculative_tokens_per_sec"));
        assert!(!pinned("speculative_decode/acceptance_rate"));
        assert!(!pinned("speculative_decode/rollbacks"));
    }

    #[test]
    fn shape_rows_keyed_by_dims_not_index() {
        let m = leaves(r#"{"shapes": [{"dout": 256, "din": 128, "g_per_sec": 5}]}"#);
        assert_eq!(m.get("shapes/256x128/g_per_sec"), Some(&5.0));
    }

    #[test]
    fn parser_handles_escapes_and_exponents() {
        let m = leaves(r#"{"a\n": 1e3, "b": -2.5E-1}"#);
        assert_eq!(m.get("a\n"), Some(&1000.0));
        assert_eq!(m.get("b"), Some(&-0.25));
    }
}
