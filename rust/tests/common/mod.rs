//! Shared fixtures for the integration suites (`integration.rs`,
//! `eval_integration.rs`): the artifact-gated runtime guard and the
//! tiny-model / native-compression builders that every suite used to
//! duplicate inline. A `tests/*.rs` binary opts in with `mod common;`.

// Each test binary uses a subset of these helpers; the unused rest
// must not trip `-D warnings`.
#![allow(dead_code)]

use slab::data::{EOS, PAD};
use slab::model::Params;
use slab::runtime::{ModelCfg, Runtime};
use slab::slab::{decompose, ActStats, SlabConfig, SlabLayer};
use slab::tensor::Mat;
use slab::util::rng::Pcg64;
use std::path::Path;
use std::sync::{Mutex, MutexGuard};

/// xla_extension 0.5.1 is unreliable with concurrent PJRT CPU clients
/// in one process; serialize test bodies so clients never coexist.
/// (One guard per test *binary* suffices — cargo runs binaries one at
/// a time, and the hazard is in-process only.)
static PJRT_GUARD: Mutex<()> = Mutex::new(());

/// The artifact-gated runtime: `None` (with a stderr note) when
/// `artifacts/` is absent, so every suite works on a fresh clone.
pub fn runtime() -> Option<(MutexGuard<'static, ()>, Runtime)> {
    let guard = PJRT_GUARD.lock().unwrap_or_else(|p| p.into_inner());
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping integration test: artifacts/ missing (run `make artifacts`)");
        return None;
    }
    Some((guard, Runtime::new(dir).expect("runtime")))
}

/// A 2-layer Llama-shaped config at testbed scale
/// (`ModelCfg::llama` mirrors model.py's shape contract), so the
/// native engine is exercised on every fresh clone — the manifest
/// only exists after `make artifacts`.
pub fn native_test_cfg() -> ModelCfg {
    ModelCfg::llama("native-e2e", 48, 16, 2, 4, 24, 20, 6)
}

/// A task-suite-capable tiny config: `max_seq` 48 fits every
/// prompt ⧺ option row the seven suites generate, and the vocab
/// covers `Grammar::standard()` (≤ 512 by its own test).
pub fn task_test_cfg() -> ModelCfg {
    ModelCfg::llama("native-eval", 512, 16, 1, 4, 32, 48, 6)
}

/// Params whose EOS logit row duplicates PAD's, so first-max
/// tie-breaking (PAD = 0 scans before EOS = 2) can never emit EOS —
/// sessions deterministically run to their full budget. Integration
/// twin of `coordinator::serve::test_support::eos_free_params`
/// (`cfg(test)` items are invisible to test binaries).
pub fn eos_free_params(cfg: &ModelCfg, seed: u64) -> Params {
    let mut params = Params::init(cfg, seed);
    let mut head = params.mat("lm_head");
    let pad_row = head.row(PAD as usize).to_vec();
    head.row_mut(EOS as usize).copy_from_slice(&pad_row);
    params.set_mat("lm_head", &head);
    params
}

/// Seed for the randomized suites: `SLAB_FUZZ_SEED` when set (CI pins
/// it; a failure report's seed replays locally the same way), else the
/// suite's default. Every fuzz test eprintln!s the seed it ran with.
pub fn fuzz_seed(default: u64) -> u64 {
    std::env::var("SLAB_FUZZ_SEED")
        .ok()
        .and_then(|s| s.trim().parse::<u64>().ok())
        .unwrap_or(default)
}

/// Decompose every pruned linear natively (no runtime, no artifacts):
/// (packed layers, params with the dense reconstruction Ŵ swapped in).
pub fn compress_native(params: &Params, seed: u64) -> (Vec<(String, SlabLayer)>, Params) {
    let mut rng = Pcg64::seed_from_u64(seed);
    let scfg = SlabConfig {
        iters: 4,
        svd_iters: 8,
        ..Default::default()
    };
    let mut packed = Vec::new();
    let mut swapped = params.clone();
    for (name, (_, din)) in params.cfg.pruned.clone() {
        let w = params.mat(&name);
        let stats = ActStats::from_activations(&Mat::randn(64, din, 1.0, &mut rng));
        let d = decompose(&w, &stats, &scfg).expect("decompose");
        let layer = SlabLayer::from_decomposition(&d);
        swapped.set_mat(&name, &layer.reconstruct());
        packed.push((name, layer));
    }
    (packed, swapped)
}
