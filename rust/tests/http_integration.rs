//! End-to-end tests for `slab serve --http`: the native packed engine
//! behind the continuous batcher behind the `coordinator::http`
//! front-end, driven over a real loopback socket — streaming parity,
//! cancellation freeing KV slots, `/metrics`, and the actual `slab`
//! binary. Artifact-free: everything here runs on every `cargo test`.

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

mod common;

use common::{compress_native, eos_free_params, native_test_cfg};
use slab::coordinator::http::client;
use slab::coordinator::{Backend, HttpConfig, HttpServer, SchedulerConfig, Server, ServerConfig};
use slab::model::{Params, SlabModel};
use slab::runtime::ModelCfg;
use slab::util::json::Json;

#[test]
fn http_streaming_matches_collect_and_metrics_report_ttft() {
    // The tentpole acceptance e2e, over the *packed* engine: tokens
    // stream incrementally over a real loopback socket, equal the
    // blocking collect() output token-for-token, equal the
    // engine-level reference, and /metrics reports non-zero TTFT.
    let cfg = native_test_cfg();
    let params = Params::init(&cfg, 101);
    let (packed, _) = compress_native(&params, 102);
    let reference_model = SlabModel::from_packed(&params, &packed, 1);
    let server = Server::start_with(
        Backend::NativeBatched(Box::new(SlabModel::from_packed(&params, &packed, 1))),
        ServerConfig::default(),
    );
    let http = HttpServer::bind("127.0.0.1:0", server).expect("bind loopback");
    let addr = http.addr();

    let health = client::get(addr, "/healthz").expect("healthz");
    assert_eq!(health.status, 200);

    let prompts: Vec<Vec<i32>> = vec![vec![5, 9, 14, 20], vec![7], vec![33, 34, 35]];
    let budget = 8usize;
    for prompt in &prompts {
        let reference = reference_model
            .generate_batch(&[prompt.clone()], budget)
            .remove(0);
        let body_json = Json::obj(vec![
            ("prompt", Json::arr(prompt.iter().map(|&t| Json::num(t)))),
            ("max_new", Json::from_usize(budget)),
        ]);
        // Blocking form (collect() semantics over the wire).
        let blocking = client::post(addr, "/v1/generate", &body_json.to_string())
            .expect("blocking generate");
        assert_eq!(blocking.status, 200, "{}", blocking.body);
        let (_, reply) = client::parse_generate_reply(&blocking.body).expect("parse reply");
        assert!(!reply.rejected && !reply.cancelled && !reply.evicted);
        assert_eq!(reply.tokens, reference, "blocking tokens vs engine reference");

        // Streaming form: one SSE frame per token, then a done frame.
        let mut stream_req = body_json.clone();
        stream_req.set("stream", Json::Bool(true));
        let mut sse = client::SseStream::open(addr, &stream_req.to_string()).expect("open sse");
        assert_eq!(sse.status, 200);
        let first = sse.next_frame().expect("frame").expect("id frame");
        assert!(first.get("id").as_i64().is_some());
        let mut streamed: Vec<i32> = Vec::new();
        let mut frames = 0usize;
        let mut done_stats = None;
        while let Some(frame) = sse.next_frame().expect("frame") {
            frames += 1;
            if let Some(tok) = frame.get("token").as_i64() {
                streamed.push(tok as i32);
            } else if !frame.get("done").is_null() {
                done_stats = Some((
                    frame.get("done").get("tokens").as_usize().unwrap(),
                    frame.get("done").get("ttft_ms").as_f64().unwrap(),
                ));
            } else {
                panic!("unexpected frame {frame:?}");
            }
        }
        assert_eq!(streamed, reference, "streamed tokens vs engine reference");
        let (done_tokens, ttft_ms) = done_stats.expect("terminal done frame");
        assert_eq!(done_tokens, streamed.len());
        // One frame per token plus the terminal: genuinely incremental
        // framing, not one buffered blob.
        assert_eq!(frames, streamed.len() + 1);
        if !streamed.is_empty() {
            assert!(ttft_ms > 0.0, "per-session ttft recorded");
        }
    }

    // /metrics renders the live ServeStats table with non-zero TTFT.
    let metrics = client::get(addr, "/metrics").expect("metrics");
    assert_eq!(metrics.status, 200);
    let ttft_row = metrics
        .body
        .lines()
        .find(|l| l.contains("mean_ttft_ms"))
        .expect("mean_ttft_ms row");
    let value: f64 = ttft_row
        .split('|')
        .map(str::trim)
        .filter(|c| !c.is_empty())
        .nth(1)
        .expect("value cell")
        .parse()
        .expect("numeric ttft");
    assert!(value > 0.0, "/metrics must report non-zero ttft: {ttft_row}");
    for key in ["requests", "generated_tokens", "tokens_per_sec", "cancelled"] {
        assert!(metrics.body.contains(key), "missing {key}:\n{}", metrics.body);
    }

    let stats = http.shutdown().expect("shutdown");
    assert_eq!(stats.requests, 2 * prompts.len());
    assert!(stats.ttft_samples > 0 && stats.mean_ttft_ms() > 0.0);
}

#[test]
fn http_cancel_frees_kv_slot_for_waiting_request() {
    // max_batch 1: a long-budget streaming session holds the only KV
    // slot while a second request waits in the queue; DELETEing the
    // first over a second connection must free the slot and let the
    // waiting request complete with exactly its reference tokens.
    // The slow config (dim 64, ~4k decode ticks with quadratic
    // attention cost) keeps the long session far from completion
    // through the waiter-settling sleep below, on any machine.
    let cfg = ModelCfg::llama("slow-e2e", 32, 64, 2, 2, 128, 4096, 4);
    let params = eos_free_params(&cfg, 103);
    let reference = SlabModel::from_dense(&params, 1)
        .generate_batch(&[vec![9, 8, 7]], 3)
        .remove(0);
    assert_eq!(reference.len(), 3, "EOS-free reference runs to budget");
    let server = Server::start_with(
        Backend::NativeBatched(Box::new(SlabModel::from_dense(&params, 1))),
        ServerConfig {
            sched: SchedulerConfig {
                max_batch: 1,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let http = HttpServer::bind("127.0.0.1:0", server).expect("bind loopback");
    let addr = http.addr();

    let budget = cfg.max_seq - cfg.prompt_len;
    let long_body = format!(r#"{{"prompt": [5, 6], "max_new": {budget}, "stream": true}}"#);
    let mut sse = client::SseStream::open(addr, &long_body).expect("open long stream");
    let id = sse
        .next_frame()
        .expect("frame")
        .expect("id frame")
        .get("id")
        .as_i64()
        .expect("id") as u64;
    let mut long_tokens = 0usize;
    while long_tokens < 2 {
        let frame = sse.next_frame().expect("frame").expect("stream open");
        assert!(frame.get("token").as_i64().is_some(), "early terminal: {frame:?}");
        long_tokens += 1;
    }

    // The waiter: a blocking generate that cannot start until the
    // long session's slot frees.
    let waiter = std::thread::spawn(move || {
        client::post(addr, "/v1/generate", r#"{"prompt": [9, 8, 7], "max_new": 3}"#)
            .expect("waiting generate")
    });
    std::thread::sleep(std::time::Duration::from_millis(100));

    let cancel = client::delete(addr, &format!("/v1/sessions/{id}")).expect("cancel");
    assert_eq!(cancel.status, 200);

    let mut cancelled_seen = false;
    while let Some(frame) = sse.next_frame().expect("frame") {
        if frame.get("token").as_i64().is_some() {
            long_tokens += 1;
        } else if !frame.get("done").is_null() {
            assert_eq!(frame.get("done").get("cancelled").as_bool(), Some(true));
            cancelled_seen = true;
        }
    }
    assert!(cancelled_seen, "long stream must terminate cancelled");
    assert!(
        long_tokens < budget,
        "cancellation must cut the stream short ({long_tokens} of {budget})"
    );

    let waited = waiter.join().expect("waiter thread");
    assert_eq!(waited.status, 200, "{}", waited.body);
    let (_, reply) = client::parse_generate_reply(&waited.body).expect("parse waiter");
    assert!(!reply.rejected && !reply.cancelled);
    assert_eq!(
        reply.tokens, reference,
        "the freed slot serves the waiter token-identically"
    );

    let stats = http.shutdown().expect("shutdown");
    assert_eq!(stats.cancelled, 1);
    assert_eq!(stats.requests, 2);
}

#[test]
fn http_soak_shared_prefix_streams_stay_ordered_under_concurrency() {
    // The paged-KV soak (DESIGN.md §13): 64 concurrent streaming
    // clients over 4 distinct prompts, so ~94% of admissions join a
    // cached prefill copy-on-write. Every stream must keep its
    // integrity under the churn — id frame first, tokens in engine
    // order, exactly one terminal frame — and /metrics must report
    // the non-zero prefix hit-rate.
    let cfg = native_test_cfg();
    let params = Params::init(&cfg, 105);
    let reference_model = SlabModel::from_dense(&params, 1);
    let prompts: Vec<Vec<i32>> = vec![
        vec![5, 9, 14, 20],
        vec![7, 8],
        vec![33, 34, 35],
        vec![11, 12, 13, 14, 15],
    ];
    let budget = 6usize;
    let reference: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| reference_model.generate_batch(&[p.clone()], budget).remove(0))
        .collect();
    let server = Server::start_with(
        Backend::NativeBatched(Box::new(SlabModel::from_dense(&params, 1))),
        ServerConfig {
            queue_cap: 128,
            sched: SchedulerConfig {
                max_batch: 8,
                queue_cap: 128,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let http = HttpServer::bind("127.0.0.1:0", server).expect("bind loopback");
    let addr = http.addr();

    let n_clients = 64usize;
    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            let pidx = i % prompts.len();
            let prompt = prompts[pidx].clone();
            std::thread::spawn(move || -> (usize, Vec<i32>) {
                let body = Json::obj(vec![
                    ("prompt", Json::arr(prompt.iter().map(|&t| Json::num(t)))),
                    ("max_new", Json::from_usize(budget)),
                    ("stream", Json::Bool(true)),
                ]);
                let mut sse = client::SseStream::open(addr, &body.to_string()).expect("open sse");
                assert_eq!(sse.status, 200);
                let id_frame = sse.next_frame().expect("frame").expect("id frame");
                assert!(id_frame.get("id").as_i64().is_some(), "id frame must come first");
                let mut tokens: Vec<i32> = Vec::new();
                let mut terminals = 0usize;
                while let Some(frame) = sse.next_frame().expect("frame") {
                    if let Some(t) = frame.get("token").as_i64() {
                        assert_eq!(terminals, 0, "token frame after the terminal");
                        tokens.push(t as i32);
                    } else if !frame.get("done").is_null() {
                        terminals += 1;
                        assert_eq!(
                            frame.get("done").get("tokens").as_usize(),
                            Some(tokens.len()),
                            "terminal token count vs streamed"
                        );
                    } else {
                        panic!("unexpected frame {frame:?}");
                    }
                }
                assert_eq!(terminals, 1, "exactly one terminal frame");
                (pidx, tokens)
            })
        })
        .collect();
    for h in handles {
        let (pidx, tokens) = h.join().expect("client thread");
        assert_eq!(
            tokens, reference[pidx],
            "soak stream diverged from the engine reference (prompt {pidx})"
        );
    }

    // /metrics sees the warm prefix cache: one miss per distinct
    // prompt, a hit for every other admission.
    let metrics = client::get(addr, "/metrics").expect("metrics");
    assert_eq!(metrics.status, 200);
    let cell = |key: &str| -> f64 {
        metrics
            .body
            .lines()
            .find(|l| l.contains(key))
            .unwrap_or_else(|| panic!("missing {key} row:\n{}", metrics.body))
            .split('|')
            .map(str::trim)
            .filter(|c| !c.is_empty())
            .nth(1)
            .expect("value cell")
            .parse()
            .expect("numeric cell")
    };
    assert!(cell("prefix_hit_rate") > 0.9, "soak must be hit-dominated");
    assert!(cell("prefix_hits") >= (n_clients - prompts.len()) as f64);

    let stats = http.shutdown().expect("shutdown");
    assert_eq!(stats.requests, n_clients);
    assert_eq!(stats.prefix_hits, n_clients - prompts.len());
    assert_eq!(stats.prefix_misses, prompts.len());
    assert!(stats.cow_splits > 0, "divergence after a shared prefix COW-splits");
}

#[test]
fn http_speculative_stream_matches_plain_and_metrics_report_acceptance() {
    // The speculative-decode e2e (DESIGN.md §14): the lossless
    // contract holds over the wire. A `speculate` session must stream
    // frame-for-frame like a plain one — same token frames in the
    // same order, same one-frame-per-token cadence, same terminal —
    // and /metrics must report the acceptance-rate counters. The
    // parity leg runs the *packed* engine, where the draft path
    // (sparse + low-rank, no bit-planes) genuinely diverges from the
    // full forward; the metrics leg uses the dense anchor, where the
    // draft view falls through to the full forward and the served
    // acceptance rate is therefore exactly 1.0.
    let cfg = native_test_cfg();
    let params = eos_free_params(&cfg, 106);
    let (packed, _) = compress_native(&params, 107);
    let budget = 8usize;
    let prompts: Vec<Vec<i32>> = vec![vec![5, 9, 14, 20], vec![7], vec![33, 34, 35]];

    let spin = |speculate: bool, dense: bool| {
        let model = if dense {
            SlabModel::from_dense(&params, 1)
        } else {
            SlabModel::from_packed(&params, &packed, 1)
        };
        let server = Server::start_with(
            Backend::NativeBatched(Box::new(model)),
            ServerConfig {
                sched: SchedulerConfig {
                    speculate,
                    draft_len: 3,
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        HttpServer::bind("127.0.0.1:0", server).expect("bind loopback")
    };
    // One streamed session: (tokens in frame order, total frame count
    // after the id frame). Timing fields differ run to run, so
    // "frame-for-frame identical" means framing and payload tokens.
    let stream_tokens = |addr: std::net::SocketAddr, prompt: &[i32]| -> (Vec<i32>, usize) {
        let body = Json::obj(vec![
            ("prompt", Json::arr(prompt.iter().map(|&t| Json::num(t)))),
            ("max_new", Json::from_usize(budget)),
            ("stream", Json::Bool(true)),
        ]);
        let mut sse = client::SseStream::open(addr, &body.to_string()).expect("open sse");
        assert_eq!(sse.status, 200);
        let first = sse.next_frame().expect("frame").expect("id frame");
        assert!(first.get("id").as_i64().is_some());
        let mut tokens: Vec<i32> = Vec::new();
        let mut frames = 0usize;
        let mut done = false;
        while let Some(frame) = sse.next_frame().expect("frame") {
            frames += 1;
            if let Some(t) = frame.get("token").as_i64() {
                assert!(!done, "token frame after the terminal");
                tokens.push(t as i32);
            } else if !frame.get("done").is_null() {
                assert_eq!(
                    frame.get("done").get("tokens").as_usize(),
                    Some(tokens.len()),
                    "terminal token count vs streamed"
                );
                done = true;
            } else {
                panic!("unexpected frame {frame:?}");
            }
        }
        assert!(done, "stream must end with a done frame");
        (tokens, frames)
    };

    // Parity leg: packed engine, plain vs speculative, frame for frame.
    let plain = spin(false, false);
    let spec = spin(true, false);
    for prompt in &prompts {
        let (p_tokens, p_frames) = stream_tokens(plain.addr(), prompt);
        let (s_tokens, s_frames) = stream_tokens(spec.addr(), prompt);
        assert_eq!(
            s_tokens, p_tokens,
            "speculative stream diverged from plain greedy (prompt {prompt:?})"
        );
        assert_eq!(s_frames, p_frames, "same framing (prompt {prompt:?})");
        assert_eq!(p_frames, p_tokens.len() + 1, "one frame per token + terminal");
        assert_eq!(p_tokens.len(), budget, "EOS-free params run to budget");
    }
    let plain_stats = plain.shutdown().expect("shutdown plain");
    assert_eq!(plain_stats.spec_rounds, 0, "plain mode never speculates");
    let spec_stats = spec.shutdown().expect("shutdown spec");
    assert!(spec_stats.spec_rounds > 0 && spec_stats.spec_drafted > 0);
    assert!(spec_stats.spec_accepted <= spec_stats.spec_drafted);

    // Metrics leg: dense anchor — every draft token verifies, so
    // /metrics reports a non-zero acceptance rate of exactly 1.0.
    let dense_spec = spin(true, true);
    let addr = dense_spec.addr();
    let (tokens, _) = stream_tokens(addr, &prompts[0]);
    assert_eq!(tokens.len(), budget);
    let metrics = client::get(addr, "/metrics").expect("metrics");
    assert_eq!(metrics.status, 200);
    let cell = |key: &str| -> f64 {
        metrics
            .body
            .lines()
            .find(|l| l.contains(key))
            .unwrap_or_else(|| panic!("missing {key} row:\n{}", metrics.body))
            .split('|')
            .map(str::trim)
            .filter(|c| !c.is_empty())
            .nth(1)
            .expect("value cell")
            .parse()
            .expect("numeric cell")
    };
    assert!(cell("spec_rounds") > 0.0);
    assert!(cell("spec_drafted") > 0.0);
    assert!(
        (cell("spec_acceptance_rate") - 1.0).abs() < 1e-9,
        "dense draft == full model, so served acceptance is exactly 1.0"
    );
    assert_eq!(cell("spec_rollbacks"), 0.0);
    let stats = dense_spec.shutdown().expect("shutdown dense spec");
    assert_eq!(stats.spec_accepted, stats.spec_drafted);
    assert_eq!(stats.spec_rollbacks, 0);
}

/// Kill-on-drop guard so a failing assert never leaks the child.
struct ChildGuard(std::process::Child);

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// Spawn `slab serve --http 127.0.0.1:0 <extra args>` and parse the
/// bound ephemeral address off its stdout.
fn spawn_serve_http(exe: &str, extra: &[&str]) -> (ChildGuard, std::net::SocketAddr) {
    use std::io::BufRead;
    let mut args = vec!["serve", "--http", "127.0.0.1:0", "--model", "small"];
    args.extend_from_slice(extra);
    let child = std::process::Command::new(exe)
        .args(&args)
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn slab serve --http");
    let mut guard = ChildGuard(child);
    let stdout = guard.0.stdout.take().expect("child stdout");
    let mut reader = std::io::BufReader::new(stdout);
    let mut addr = None;
    for _ in 0..10 {
        let mut line = String::new();
        if reader.read_line(&mut line).unwrap_or(0) == 0 {
            break;
        }
        if let Some(rest) = line.trim().strip_prefix("listening on http://") {
            addr = Some(rest.parse::<std::net::SocketAddr>().expect("addr"));
            break;
        }
    }
    (guard, addr.expect("`listening on http://...` line on stdout"))
}

#[test]
fn slab_serve_http_binary_serves_over_loopback() {
    // The actual CLI: spawn `slab serve --http 127.0.0.1:0`, parse the
    // bound address off stdout, and drive it over the socket. A second
    // child with `--speculate` must serve the identical tokens (the
    // lossless contract through the real binary and flag parsing) and
    // report the acceptance counters on /metrics.
    let Some(exe) = option_env!("CARGO_BIN_EXE_slab") else {
        eprintln!("skipping: CARGO_BIN_EXE_slab not set");
        return;
    };
    let (_guard, addr) = spawn_serve_http(exe, &[]);

    let health = client::get(addr, "/healthz").expect("healthz");
    assert_eq!(health.status, 200);
    let body = r#"{"prompt": [5, 6, 7], "max_new": 4}"#;
    let first = client::post(addr, "/v1/generate", body).expect("generate");
    assert_eq!(first.status, 200, "{}", first.body);
    let (_, r1) = client::parse_generate_reply(&first.body).expect("parse");
    assert!(r1.tokens.len() <= 4);
    let second = client::post(addr, "/v1/generate", body).expect("generate again");
    let (_, r2) = client::parse_generate_reply(&second.body).expect("parse");
    assert_eq!(r1.tokens, r2.tokens, "the served model is deterministic");
    let metrics = client::get(addr, "/metrics").expect("metrics");
    assert!(metrics.body.contains("requests"), "{}", metrics.body);

    // Same model seed, `--speculate --draft-len 3`: identical output.
    let (_spec_guard, spec_addr) = spawn_serve_http(exe, &["--speculate", "--draft-len", "3"]);
    let spec = client::post(spec_addr, "/v1/generate", body).expect("speculative generate");
    assert_eq!(spec.status, 200, "{}", spec.body);
    let (_, r3) = client::parse_generate_reply(&spec.body).expect("parse");
    assert_eq!(r3.tokens, r1.tokens, "--speculate must not change the stream");
    let spec_metrics = client::get(spec_addr, "/metrics").expect("spec metrics");
    assert!(
        spec_metrics.body.contains("spec_acceptance_rate"),
        "{}",
        spec_metrics.body
    );
    // ChildGuards kill both servers on drop.
}

// ---------------------------------------------------------------------
// Wire-contract corpus + event-loop policy tests (ISSUE 9)
// ---------------------------------------------------------------------

/// Read one framed reply (status line, headers, `Content-Length`
/// body) off an already-connected reader. Returns (status, headers
/// lower-cased one-per-line, body).
fn read_framed_reply(
    reader: &mut std::io::BufReader<std::net::TcpStream>,
) -> (u16, String, String) {
    use std::io::{BufRead, Read};
    let mut status_line = String::new();
    reader.read_line(&mut status_line).expect("status line");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let mut headers = String::new();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h).expect("header") == 0 {
            break;
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().unwrap_or(0);
            }
        }
        headers.push_str(&h.to_ascii_lowercase());
        headers.push('\n');
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).expect("body");
    (status, headers, String::from_utf8_lossy(&body).into_owned())
}

/// Write raw request bytes on a fresh connection and read one framed
/// reply — the malformed-request corpus cannot go through the
/// well-behaved `client` helpers.
fn raw_roundtrip(addr: std::net::SocketAddr, request: &[u8]) -> (u16, String, String) {
    use std::io::Write;
    let mut stream = std::net::TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(30)))
        .expect("timeout");
    stream.write_all(request).expect("write raw request");
    let mut reader = std::io::BufReader::new(stream);
    read_framed_reply(&mut reader)
}

#[test]
fn http_wire_contract_malformed_request_corpus() {
    // Every satellite wire-contract fix, pinned over a raw socket:
    // exact status codes and problem-body shape. None of these may
    // reach the engine (requests == 0 at shutdown).
    let cfg = native_test_cfg();
    let params = Params::init(&cfg, 108);
    let server = Server::start_with(
        Backend::NativeBatched(Box::new(SlabModel::from_dense(&params, 1))),
        ServerConfig::default(),
    );
    let http = HttpServer::bind("127.0.0.1:0", server).expect("bind loopback");
    let addr = http.addr();

    // Chunked transfer: refused with 411 + problem body, not silently
    // misread as an empty body followed by garbage.
    let (status, headers, body) = raw_roundtrip(
        addr,
        b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nTransfer-Encoding: chunked\r\n\r\n5\r\n{\"p\":\r\n0\r\n\r\n",
    );
    assert_eq!(status, 411, "{body}");
    assert!(headers.contains("application/problem+json"), "{headers}");
    assert!(body.contains("urn:slab:problem:length-required"), "{body}");
    assert!(body.contains("\"field\":\"Transfer-Encoding\""), "{body}");

    // Lowercase / wrong-case methods: 405 with Allow (RFC 9110 §9.1),
    // never a silent alias of the uppercase method.
    let (status, headers, body) =
        raw_roundtrip(addr, b"get /healthz HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n");
    assert_eq!(status, 405, "{body}");
    assert!(headers.contains("allow: get"), "{headers}");
    assert!(body.contains("urn:slab:problem:method-not-allowed"), "{body}");
    let (status, headers, _) = raw_roundtrip(
        addr,
        b"Post /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\nConnection: close\r\n\r\n",
    );
    assert_eq!(status, 405);
    assert!(headers.contains("allow: post"), "{headers}");

    // Query strings route instead of 404ing.
    let (status, _, body) = raw_roundtrip(
        addr,
        b"GET /healthz?probe=1 HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
    );
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"status\":\"ok\""), "{body}");
    let (status, _, body) = raw_roundtrip(
        addr,
        b"GET /metrics?format=json HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
    );
    assert_eq!(status, 200, "{body}");
    let v = Json::parse(&body).expect("metrics json body");
    assert!(v.get("requests").as_usize().is_some(), "{body}");

    // Oversized header line: 431.
    let mut big = Vec::from(&b"GET /healthz HTTP/1.1\r\nX-Big: "[..]);
    big.extend(vec![b'a'; 9000]);
    big.extend_from_slice(b"\r\n\r\n");
    let (status, _, body) = raw_roundtrip(addr, &big);
    assert_eq!(status, 431, "{body}");
    assert!(body.contains("urn:slab:problem:"), "{body}");

    // Bad and overflowing Content-Length: 400 with field context.
    let (status, _, body) = raw_roundtrip(
        addr,
        b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: nope\r\n\r\n",
    );
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("\"field\":\"Content-Length\""), "{body}");
    let (status, _, body) = raw_roundtrip(
        addr,
        b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 99999999999999999999999\r\n\r\n",
    );
    assert_eq!(status, 400, "{body}");
    // In-range but over the body cap: 413.
    let (status, _, body) = raw_roundtrip(
        addr,
        b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 2000000\r\n\r\n",
    );
    assert_eq!(status, 413, "{body}");

    // Garbage request line and unsupported version.
    let (status, _, _) = raw_roundtrip(addr, b"GARBAGE\r\n\r\n");
    assert_eq!(status, 400);
    let (status, _, _) = raw_roundtrip(addr, b"GET /healthz HTTP/2.0\r\nHost: x\r\n\r\n");
    assert_eq!(status, 505);

    // Pipelined keep-alive: two requests in one write, two in-order
    // framed replies on the same socket.
    {
        use std::io::Write;
        let mut stream = std::net::TcpStream::connect(addr).expect("connect");
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(30)))
            .expect("timeout");
        stream
            .write_all(
                b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\nGET /healthz HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
            )
            .expect("write pipelined pair");
        let mut reader = std::io::BufReader::new(stream);
        let (s1, h1, b1) = read_framed_reply(&mut reader);
        let (s2, h2, b2) = read_framed_reply(&mut reader);
        assert_eq!((s1, s2), (200, 200), "{b1} / {b2}");
        assert!(h1.contains("connection: keep-alive"), "{h1}");
        assert!(h2.contains("connection: close"), "{h2}");
        assert!(b1.contains("\"status\":\"ok\"") && b2.contains("\"status\":\"ok\""));
    }

    let stats = http.shutdown().expect("shutdown");
    assert_eq!(stats.requests, 0, "no malformed request reaches the engine");
}

#[test]
fn http_429_carries_retry_after() {
    // queue_cap 1 + max_batch 1 on the slow config: one session
    // decoding, one waiting at the admission gate; the next
    // submission is rejected synchronously and must carry Retry-After
    // (header + `retry_after_secs` problem member) — blocking and
    // streaming alike.
    let cfg = ModelCfg::llama("slow-429", 32, 64, 2, 2, 128, 4096, 4);
    let params = eos_free_params(&cfg, 109);
    let server = Server::start_with(
        Backend::NativeBatched(Box::new(SlabModel::from_dense(&params, 1))),
        ServerConfig {
            queue_cap: 1,
            sched: SchedulerConfig {
                max_batch: 1,
                queue_cap: 1,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let http = HttpServer::bind("127.0.0.1:0", server).expect("bind loopback");
    let addr = http.addr();
    let budget = cfg.max_seq - cfg.prompt_len;
    let long = format!(r#"{{"prompt": [5, 6], "max_new": {budget}, "stream": true}}"#);

    let mut a = client::SseStream::open(addr, &long).expect("open A");
    assert_eq!(a.status, 200);
    let a_id = a
        .next_frame()
        .expect("frame")
        .expect("id frame")
        .get("id")
        .as_i64()
        .expect("id") as u64;
    // One token: A has departed the gate and holds the decode slot.
    let f = a.next_frame().expect("frame").expect("token frame");
    assert!(f.get("token").as_i64().is_some(), "{f:?}");

    let mut b = client::SseStream::open(addr, &long).expect("open B");
    assert_eq!(b.status, 200, "B queues at the gate, not rejected");
    let b_id = b
        .next_frame()
        .expect("frame")
        .expect("id frame")
        .get("id")
        .as_i64()
        .expect("id") as u64;

    // Gate full: a blocking submission bounces with Retry-After.
    let refused =
        client::post(addr, "/v1/generate", r#"{"prompt": [5], "max_new": 2}"#).expect("reply");
    assert_eq!(refused.status, 429, "{}", refused.body);
    let retry: u64 = refused
        .header("retry-after")
        .expect("429 must carry Retry-After")
        .parse()
        .expect("numeric Retry-After");
    assert!(retry >= 1, "Retry-After must be at least a second");
    assert!(refused.body.contains("urn:slab:problem:queue-full"), "{}", refused.body);
    assert!(refused.body.contains("retry_after_secs"), "{}", refused.body);

    // A streaming submission over a full gate gets the same plain 429
    // problem reply — no SSE preamble to a doomed stream.
    let mut rejected_stream =
        client::SseStream::open(addr, r#"{"prompt": [5], "max_new": 2, "stream": true}"#)
            .expect("open rejected stream");
    assert_eq!(rejected_stream.status, 429);
    assert!(rejected_stream.header("retry-after").is_some());
    let body = rejected_stream.read_body().expect("problem body");
    assert!(body.contains("urn:slab:problem:queue-full"), "{body}");

    for id in [a_id, b_id] {
        let c = client::delete(addr, &format!("/v1/sessions/{id}")).expect("cancel");
        assert_eq!(c.status, 200);
    }
    while a.next_frame().expect("frame").is_some() {}
    while b.next_frame().expect("frame").is_some() {}
    let stats = http.shutdown().expect("shutdown");
    assert_eq!(stats.rejected, 2, "blocking + streaming rejections both count");
    assert!(stats.cancelled >= 1, "the decoding session was cancelled");
}

#[cfg(target_os = "linux")]
#[test]
fn http_slow_client_write_budget_cancels_session() {
    use slab::util::evloop::connect_with_rcvbuf;
    // Tiny socket buffers + a 2 KiB write budget + a short stall cap:
    // a client that opens a stream and never reads must get its
    // session cancelled and its socket closed — long before the
    // multi-thousand-token budget is produced.
    let cfg = ModelCfg::llama("slow-stall", 32, 64, 2, 2, 128, 4096, 4);
    let params = eos_free_params(&cfg, 110);
    let server = Server::start_with(
        Backend::NativeBatched(Box::new(SlabModel::from_dense(&params, 1))),
        ServerConfig {
            sched: SchedulerConfig {
                max_batch: 1,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let http = HttpServer::bind_with(
        "127.0.0.1:0",
        server,
        HttpConfig {
            sndbuf: 4096,
            write_budget: 2048,
            write_stall: std::time::Duration::from_millis(500),
            ..HttpConfig::default()
        },
    )
    .expect("bind loopback");
    let addr = http.addr();

    // SO_RCVBUF must be set before connect to cap the TCP window.
    let mut stream = connect_with_rcvbuf(addr, 4096).expect("connect with tiny rcvbuf");
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(60)))
        .expect("timeout");
    let budget = cfg.max_seq - cfg.prompt_len;
    let body = format!(r#"{{"prompt": [5, 6], "max_new": {budget}, "stream": true}}"#);
    {
        use std::io::Write;
        write!(
            stream,
            "POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
        .expect("send request");
    }
    // Read NOTHING: the kernel windows fill, the server's write
    // budget/stall policy trips, and the session is cancelled. Watch
    // it land via /metrics (bounded wait).
    let t0 = std::time::Instant::now();
    loop {
        let m = client::get(addr, "/metrics?format=json").expect("metrics");
        let v = Json::parse(&m.body).expect("metrics json");
        if v.get("cancelled").as_usize() == Some(1) {
            break;
        }
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(120),
            "server never cancelled the stalled client's session:\n{}",
            m.body
        );
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    // The socket was killed server-side: draining it yields only the
    // kernel-buffered prefix, then EOF/reset — not the full stream.
    use std::io::Read;
    let mut total = 0usize;
    let mut buf = [0u8; 4096];
    loop {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => total += n,
            Err(_) => break,
        }
    }
    assert!(
        total < 64 * 1024,
        "only the buffered prefix should have been delivered ({total} bytes)"
    );
    let stats = http.shutdown().expect("shutdown");
    assert_eq!(stats.cancelled, 1);
    assert_eq!(
        stats.dropped_clients, 0,
        "the worker drains the terminal event even for a killed socket"
    );
}

#[test]
fn http_soak_256_concurrent_streams_through_event_loop() {
    // The event-loop acceptance soak (ISSUE 9): 256 concurrent
    // streaming connections — 16x the worker pool — all complete
    // through one loop thread with ordered frames (id first, tokens
    // in engine order, exactly one terminal) and exact terminal
    // accounting at shutdown.
    let cfg = native_test_cfg();
    let params = Params::init(&cfg, 111);
    let reference_model = SlabModel::from_dense(&params, 1);
    let prompts: Vec<Vec<i32>> = vec![
        vec![5, 9, 14, 20],
        vec![7, 8],
        vec![33, 34, 35],
        vec![11, 12, 13, 14, 15],
    ];
    let budget = 4usize;
    let reference: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| reference_model.generate_batch(&[p.clone()], budget).remove(0))
        .collect();
    let server = Server::start_with(
        Backend::NativeBatched(Box::new(SlabModel::from_dense(&params, 1))),
        ServerConfig {
            queue_cap: 512,
            sched: SchedulerConfig {
                max_batch: 8,
                queue_cap: 512,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let http = HttpServer::bind_with(
        "127.0.0.1:0",
        server,
        HttpConfig {
            max_conns: 512,
            workers: 16,
            ..HttpConfig::default()
        },
    )
    .expect("bind loopback");
    let addr = http.addr();

    let n_clients = 256usize;
    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            let pidx = i % prompts.len();
            let prompt = prompts[pidx].clone();
            std::thread::spawn(move || -> (usize, Vec<i32>) {
                let body = Json::obj(vec![
                    ("prompt", Json::arr(prompt.iter().map(|&t| Json::num(t)))),
                    ("max_new", Json::from_usize(budget)),
                    ("stream", Json::Bool(true)),
                ]);
                let mut sse = client::SseStream::open(addr, &body.to_string()).expect("open sse");
                assert_eq!(sse.status, 200);
                let id_frame = sse.next_frame().expect("frame").expect("id frame");
                assert!(id_frame.get("id").as_i64().is_some(), "id frame must come first");
                let mut tokens: Vec<i32> = Vec::new();
                let mut terminals = 0usize;
                while let Some(frame) = sse.next_frame().expect("frame") {
                    if let Some(t) = frame.get("token").as_i64() {
                        assert_eq!(terminals, 0, "token frame after the terminal");
                        tokens.push(t as i32);
                    } else if !frame.get("done").is_null() {
                        terminals += 1;
                        assert_eq!(
                            frame.get("done").get("tokens").as_usize(),
                            Some(tokens.len()),
                            "terminal token count vs streamed"
                        );
                    } else {
                        panic!("unexpected frame {frame:?}");
                    }
                }
                assert_eq!(terminals, 1, "exactly one terminal frame");
                (pidx, tokens)
            })
        })
        .collect();
    let mut completed = 0usize;
    for h in handles {
        let (pidx, tokens) = h.join().expect("client thread");
        assert_eq!(
            tokens, reference[pidx],
            "soak stream diverged from the engine reference (prompt {pidx})"
        );
        completed += 1;
    }
    assert_eq!(completed, n_clients);

    let stats = http.shutdown().expect("shutdown");
    assert_eq!(stats.requests, n_clients, "exact terminal accounting");
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.cancelled, 0);
    assert_eq!(stats.dropped_clients, 0);
}
