//! Integration tests over the AOT artifacts (L1/L2) driven from L3.
//!
//! These require `make artifacts`; every test skips (with a stderr
//! note) when `artifacts/manifest.json` is absent so `cargo test`
//! works on a fresh clone.

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

mod common;

use common::{compress_native, eos_free_params, fuzz_seed, native_test_cfg, runtime};
use slab::coordinator::{
    collect_events, load_packed_checkpoint, Backend, BudgetConfig, CancelHandle, CompressJob,
    Event, Request, Scheduler, SchedulerConfig, Server, ServerConfig,
};
use slab::data::{build_corpus, Grammar};
use slab::model::{Params, SlabModel};
use slab::runtime::{lit_f32, lit_i32, lit_scalar_i32, to_vec_f32};
use slab::slab::{decompose, ActStats, RefineConfig, RefineReport, SlabConfig, SlabLayer};
use slab::tensor::Mat;
use slab::util::rng::Pcg64;
use std::path::Path;
use std::sync::mpsc::{channel, Receiver};
use std::time::Duration;

#[test]
fn manifest_covers_all_configs_and_kernels() {
    let Some((_guard, rt)) = runtime() else { return };
    for cname in ["small", "base", "large"] {
        let cfg = rt.manifest.config(cname).expect(cname);
        assert_eq!(cfg.pruned.len(), 7 * cfg.n_layers);
        for art in ["train_step", "eval_nll", "prefill", "decode_step", "slab_fwd",
                    "embed", "block_capture"] {
            assert!(
                rt.manifest.artifact(&format!("{art}_{cname}")).is_some(),
                "{art}_{cname} missing"
            );
        }
        for (_, (dout, din)) in &cfg.pruned {
            assert!(rt
                .manifest
                .artifact(&format!("decompose_{dout}x{din}"))
                .is_some());
        }
    }
}

#[test]
fn artifact_decompose_matches_native() {
    // The paper-faithful L1/Pallas path and the native rust twin must
    // agree: same sparsity, same signs, reconstruction errors within a
    // few percent (SVD init differs: ones-init power iteration vs
    // seeded random — masks may differ at threshold boundaries).
    let Some((_guard, rt)) = runtime() else { return };
    let (dout, din) = (64usize, 176usize);
    let mut rng = Pcg64::seed_from_u64(4242);
    let w = Mat::randn(dout, din, 0.05, &mut rng);
    let x = Mat::randn(256, din, 1.0, &mut rng);
    let stats = ActStats::from_activations(&x);
    let cfg = SlabConfig {
        iters: 8,
        svd_iters: 30,
        ..Default::default()
    };
    let keep = cfg.keep_fraction(dout, din).unwrap();

    let native = decompose(&w, &stats, &cfg).unwrap();
    let outs = rt
        .execute(
            &format!("decompose_{dout}x{din}"),
            &[
                lit_f32(&w.data, &[dout, din]),
                lit_f32(&stats.col_norms, &[din]),
                slab::runtime::literal::lit_scalar_f32(keep as f32),
                lit_scalar_i32(8),
            ],
        )
        .unwrap();
    let ws_a = Mat::from_vec(dout, din, to_vec_f32(&outs[0]));
    let u_a = to_vec_f32(&outs[1]);
    let v_a = to_vec_f32(&outs[2]);
    let wb_a = Mat::from_vec(dout, din, to_vec_f32(&outs[3]));

    // Same per-row sparsity.
    let per_row = (keep * din as f64).floor() as usize;
    for i in 0..dout {
        let nnz = ws_a.row(i).iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nnz, per_row, "artifact row {i}");
    }
    // W_B strictly ±1 and mostly agreeing with native.
    assert!(wb_a.data.iter().all(|&b| b == 1.0 || b == -1.0));
    let agree = wb_a
        .data
        .iter()
        .zip(native.w_b.data.iter())
        .filter(|(a, b)| a == b)
        .count();
    assert!(
        agree as f64 / wb_a.numel() as f64 > 0.95,
        "sign agreement {agree}/{}",
        wb_a.numel()
    );
    // Reconstruction errors within 5% of each other.
    let rec_a = ws_a.add(&Mat::outer(&u_a, &v_a).hadamard(&wb_a));
    let err_a = w.frob_dist(&rec_a);
    let err_n = w.frob_dist(&native.reconstruct());
    assert!(
        (err_a - err_n).abs() / err_n < 0.05,
        "artifact {err_a} vs native {err_n}"
    );
}

#[test]
fn train_step_decreases_loss() {
    let Some((_guard, rt)) = runtime() else { return };
    let cfg = rt.manifest.config("small").unwrap().clone();
    let g = Grammar::standard();
    let corpus = build_corpus(&g, 1, 64, 8, 8, cfg.max_seq);
    let init = Params::init(&cfg, 3);
    let (_, report) =
        slab::train::train(&rt, &init, &corpus.train, 30, 5, 10).expect("train");
    let first = report.loss_curve.first().unwrap().1;
    assert!(
        report.final_loss < first * 0.85,
        "loss {first} → {}",
        report.final_loss
    );
}

#[test]
fn eval_nll_is_deterministic_and_positive() {
    let Some((_guard, rt)) = runtime() else { return };
    let cfg = rt.manifest.config("small").unwrap().clone();
    let params = Params::init(&cfg, 9);
    let g = Grammar::standard();
    let corpus = build_corpus(&g, 2, 8, 16, 8, cfg.max_seq);
    let p1 = slab::eval::perplexity(&rt, &params, &corpus.valid).unwrap();
    let p2 = slab::eval::perplexity(&rt, &params, &corpus.valid).unwrap();
    assert_eq!(p1, p2);
    // Untrained model ≈ uniform: ppl near vocab size.
    assert!(p1 > 50.0 && p1 < 2.0 * cfg.vocab as f64, "ppl {p1}");
}

#[test]
fn slab_fwd_artifact_matches_dense_identity_encoding() {
    // Encode every pruned linear as (ws=W, u=0, v=0, b=1) — the
    // Pallas compressed forward must reproduce dense logits. This is
    // the L1→L2→L3 composition check at the whole-model level.
    let Some((_guard, rt)) = runtime() else { return };
    let cfg = rt.manifest.config("small").unwrap().clone();
    let params = Params::init(&cfg, 11);
    let b = rt.manifest.serve_batch;
    let t = cfg.prompt_len;
    let tokens: Vec<i32> = (0..b * t).map(|i| 5 + (i as i32 % 40)).collect();

    // slab_fwd inputs in slab_param_names order.
    let mut inputs: Vec<xla::Literal> = Vec::new();
    for (name, shape) in cfg.param_names.iter().zip(cfg.param_shapes.iter()) {
        let idx = params.index(name).unwrap();
        let base = name.rsplit('.').next().unwrap();
        let is_pruned = matches!(
            base,
            "wq" | "wk" | "wv" | "wo" | "w_gate" | "w_up" | "w_down"
        );
        if is_pruned {
            let (dout, din) = (shape[0], shape[1]);
            inputs.push(lit_f32(&params.tensors[idx], shape)); // ws = W
            inputs.push(lit_f32(&vec![0.0; dout], &[dout])); // u = 0
            inputs.push(lit_f32(&vec![0.0; din], &[din])); // v = 0
            inputs.push(lit_f32(&vec![1.0; dout * din], &[dout, din])); // b = 1
        } else {
            inputs.push(lit_f32(&params.tensors[idx], shape));
        }
    }
    inputs.push(lit_i32(&tokens, &[b, t]));
    let outs = rt
        .execute(&format!("slab_fwd_{}", cfg.name), &inputs)
        .expect("slab_fwd");
    let logits = to_vec_f32(&outs[0]);
    assert_eq!(logits.len(), b * t * cfg.vocab);
    assert!(logits.iter().all(|v| v.is_finite()));

    // Dense reference: prefill's last-position logits must match the
    // compressed forward at the last position.
    let mut pin: Vec<xla::Literal> = params.to_literals();
    pin.push(lit_i32(&tokens, &[b, t]));
    let pouts = rt
        .execute(&format!("prefill_{}", cfg.name), &pin)
        .expect("prefill");
    let plogits = to_vec_f32(&pouts[0]);
    for s in 0..b {
        for vtok in 0..cfg.vocab {
            let a = logits[(s * t + (t - 1)) * cfg.vocab + vtok];
            let d = plogits[s * cfg.vocab + vtok];
            assert!(
                (a - d).abs() < 2e-3 * (1.0 + d.abs()),
                "seq {s} tok {vtok}: slab_fwd {a} vs prefill {d}"
            );
        }
    }
}

#[test]
fn server_serves_every_request_exactly_once() {
    // Router/batcher invariants: every submitted request gets exactly
    // one response; batches never exceed serve_batch; generation stops
    // at the token budget.
    let Some((_guard, rt)) = runtime() else { return };
    let cfg = rt.manifest.config("small").unwrap().clone();
    let cap = rt.manifest.serve_batch;
    let params = Params::init(&cfg, 21);
    drop(rt); // the Server's router thread owns the only PJRT client
    let server = slab::coordinator::Server::start(
        Path::new("artifacts").to_path_buf(),
        params,
        slab::coordinator::ServerConfig::default(),
    );
    let g = Grammar::standard();
    let mut rng = Pcg64::seed_from_u64(77);
    let n = 10;
    let prompts: Vec<Vec<i32>> = (0..n).map(|_| g.sample_sentence(&mut rng)).collect();
    let sessions: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            server.submit(slab::coordinator::Request {
                prompt: p.clone(),
                max_new: 3 + (i % 4),
                deadline: None,
            })
        })
        .collect();
    let mut responses = 0;
    let mut collected: Vec<Vec<i32>> = Vec::new();
    for (i, session) in sessions.into_iter().enumerate() {
        let r = session.collect();
        assert!(r.tokens.len() <= 3 + (i % 4), "token budget violated");
        assert!(r.latency_ms >= r.queue_ms);
        collected.push(r.tokens);
        responses += 1;
    }
    assert_eq!(responses, n);
    // Streaming parity on the artifact backend too: consuming the raw
    // event stream of an identical request yields exactly the tokens
    // collect() returned (the engines are deterministic).
    let session = server.submit(slab::coordinator::Request {
        prompt: prompts[0].clone(),
        max_new: 3,
        deadline: None,
    });
    let mut streamed = Vec::new();
    let mut terminal_tokens = None;
    while let Some(ev) = session.recv() {
        match ev {
            slab::coordinator::Event::Token(t) => streamed.push(t),
            slab::coordinator::Event::Done(s) | slab::coordinator::Event::Evicted(s) => {
                terminal_tokens = Some(s.tokens);
            }
            slab::coordinator::Event::Rejected => panic!("unexpected rejection"),
        }
    }
    assert_eq!(terminal_tokens, Some(streamed.len()), "one terminal event");
    assert_eq!(streamed, collected[0], "streamed vs collected tokens (artifact)");
    let stats = server.shutdown().expect("stats");
    assert_eq!(stats.requests, n + 1);
    assert!(stats.batches >= n.div_ceil(cap), "batches {}", stats.batches);
    // No batch can have exceeded cap: requests ≤ batches * cap.
    assert!(stats.requests <= stats.batches * cap);
    if stats.generated_tokens > 0 {
        assert!(stats.mean_ttft_ms() > 0.0, "ttft accounted on the artifact path");
    }
}

#[test]
fn pipeline_wanda_layerwise_matches_paper_semantics() {
    // After the pipeline, every pruned linear of a Wanda-compressed
    // model must hit the target per-row sparsity exactly, and the
    // untouched params (embeddings, norms, head) must be bit-identical.
    let Some((_guard, rt)) = runtime() else { return };
    let cfg = rt.manifest.config("small").unwrap().clone();
    let params = Params::init(&cfg, 31);
    let g = Grammar::standard();
    let corpus = build_corpus(&g, 3, 16, 8, 16, cfg.max_seq);
    let method = slab::baselines::Method::Wanda {
        sparsity: 0.5,
        pattern: None,
    };
    let out = slab::coordinator::compress_model(
        &rt,
        &params,
        &corpus.calib,
        &method,
        slab::coordinator::Engine::Native,
    )
    .expect("pipeline");
    for (name, (dout, din)) in &cfg.pruned {
        let m = out.params.mat(name);
        for i in 0..*dout {
            let nnz = m.row(i).iter().filter(|&&v| v != 0.0).count();
            assert_eq!(nnz, din / 2, "{name} row {i}");
        }
    }
    for (i, name) in cfg.param_names.iter().enumerate() {
        let base = name.rsplit('.').next().unwrap();
        if !matches!(base, "wq" | "wk" | "wv" | "wo" | "w_gate" | "w_up" | "w_down") {
            assert_eq!(out.params.tensors[i], params.tensors[i], "{name} must be untouched");
        }
    }
    // Report covers all pruned layers.
    assert_eq!(out.report.layers.len(), cfg.pruned.len());
}

#[test]
fn native_capture_cross_checks_artifact_capture() {
    // ISSUE-3 acceptance: native-capture compression of a small config
    // vs the serial artifact-engine path. The two capture engines
    // differ only by f32 summation order inside the forward, so the
    // structural outputs (layer coverage, exact per-row kept counts)
    // must be identical and the reconstruction errors must land within
    // a tight band. The native path is fed the artifact engine's
    // batching (eval_batch) so the statistics pool over the same rows.
    let Some((_guard, rt)) = runtime() else { return };
    let cfg = rt.manifest.config("small").unwrap().clone();
    let params = Params::init(&cfg, 33);
    let g = Grammar::standard();
    let corpus = build_corpus(&g, 5, 16, 8, 16, cfg.max_seq);
    let method = slab::baselines::Method::Wanda {
        sparsity: 0.5,
        pattern: None,
    };
    let art = slab::coordinator::compress_model(
        &rt,
        &params,
        &corpus.calib,
        &method,
        slab::coordinator::Engine::Native,
    )
    .expect("artifact-capture pipeline");
    let nat = slab::coordinator::CompressJob::new(&params, &corpus.calib, &method)
        .batch(rt.manifest.eval_batch)
        .run()
        .expect("native-capture pipeline");
    assert_eq!(art.report.layers.len(), nat.report.layers.len());
    for (a, b) in art.report.layers.iter().zip(nat.report.layers.iter()) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.kept, b.kept, "{}: kept counts are structural", a.name);
        assert!(
            (a.frob_err - b.frob_err).abs() <= 2e-2 * (1.0 + a.frob_err.abs()),
            "{}: artifact {} vs native {}",
            a.name,
            a.frob_err,
            b.frob_err
        );
    }
    assert!(
        (art.report.mean_frob - nat.report.mean_frob).abs()
            <= 2e-2 * (1.0 + art.report.mean_frob.abs()),
        "mean frob: artifact {} vs native {}",
        art.report.mean_frob,
        nat.report.mean_frob
    );
}

#[test]
fn artifact_capture_parallel_decompose_is_bit_identical_to_serial() {
    // Within one capture engine, parallelism must be invisible: the
    // scoped-worker decompose fan-out over the artifact-captured stats
    // reproduces the serial packed layers bit for bit.
    let Some((_guard, rt)) = runtime() else { return };
    let cfg = rt.manifest.config("small").unwrap().clone();
    let params = Params::init(&cfg, 35);
    let g = Grammar::standard();
    let corpus = build_corpus(&g, 7, 16, 8, 16, cfg.max_seq);
    let method = slab::baselines::Method::Slab(SlabConfig {
        iters: 2,
        svd_iters: 4,
        ..Default::default()
    });
    let run = |threads: usize| {
        slab::coordinator::CompressJob::new(&params, &corpus.calib, &method)
            .capture(slab::coordinator::CaptureEngine::Artifact(&rt))
            .threads(threads)
            .run()
            .expect("compress job")
    };
    let serial = run(1);
    let par = run(4);
    assert_eq!(serial.slab_layers, par.slab_layers, "packed layers");
    assert_eq!(
        serial.params.as_ref().unwrap().tensors,
        par.params.as_ref().unwrap().tensors,
        "dense reconstructions"
    );
    assert_eq!(serial.report.layers, par.report.layers, "reports");
}

// ---------------------------------------------------------------------------
// Native packed-serving engine — needs NO artifacts, runs everywhere.
// (Fixtures — the tiny llama config and the native decomposition —
// live in tests/common/mod.rs, shared with eval_integration.rs.)
// ---------------------------------------------------------------------------

#[test]
fn native_packed_serving_matches_dense_reconstruction_end_to_end() {
    // The acceptance-criterion e2e, through the full serving stack:
    // a NativePacked server consuming the compressed format directly
    // must emit token-identical responses to a server over the dense
    // reconstruction of the *same* decomposition.
    let cfg = native_test_cfg();
    let params = Params::init(&cfg, 71);
    let (packed, swapped) = compress_native(&params, 72);
    assert_eq!(packed.len(), 7 * cfg.n_layers);

    let prompts: Vec<Vec<i32>> = vec![
        vec![5, 9, 14, 20],
        vec![33, 34, 35, 36, 37, 38],
        vec![7],
        vec![40, 11, 22],
        vec![19, 18, 17, 16, 15],
    ];
    let serve = |model: SlabModel| -> Vec<Vec<i32>> {
        let server = Server::start_with(
            Backend::NativePacked(Box::new(model)),
            ServerConfig::default(),
        );
        let sessions: Vec<_> = prompts
            .iter()
            .map(|p| {
                server.submit(Request {
                    prompt: p.clone(),
                    max_new: 10,
                    deadline: None,
                })
            })
            .collect();
        let out = sessions.into_iter().map(|s| s.collect().tokens).collect();
        server.shutdown().expect("stats");
        out
    };

    let packed_model = SlabModel::from_packed(&params, &packed, 2);
    assert_eq!(packed_model.packed_linear_count(), 7 * cfg.n_layers);
    let dense_model = SlabModel::from_dense(&swapped, 1);
    assert!(packed_model.weights_nbytes() < dense_model.weights_nbytes());

    let got_packed = serve(packed_model);
    let got_dense = serve(dense_model);
    assert_eq!(got_packed, got_dense, "packed vs dense-reconstruction tokens");
    // And the whole thing is deterministic under re-serving.
    let again = serve(SlabModel::from_packed(&params, &packed, 4));
    assert_eq!(again, got_packed);
}

#[test]
fn batched_scheduler_matches_serial_packed_serving_end_to_end() {
    // The continuous-batching acceptance e2e: a NativeBatched server
    // over the *packed* engine must answer a mixed-length request set
    // token-identically to the serial NativePacked router over the
    // same compressed model — batching, prefill-then-join admission,
    // and per-session termination must never change a single token.
    let cfg = native_test_cfg();
    let params = Params::init(&cfg, 91);
    let (packed, _) = compress_native(&params, 92);

    let prompts: Vec<Vec<i32>> = vec![
        vec![5, 9, 14, 20],
        vec![33, 34, 35, 36, 37, 38, 39, 40], // longer than prompt_len
        vec![7],
        vec![],
        vec![40, 11, 22],
        vec![19, 18, 17, 16, 15],
        vec![25, 26],
    ];
    let budgets = [9usize, 4, 12, 3, 7, 1, 0];
    let serve = |backend: Backend, scfg: ServerConfig| -> Vec<Vec<i32>> {
        let server = Server::start_with(backend, scfg);
        let sessions: Vec<_> = prompts
            .iter()
            .zip(budgets.iter())
            .map(|(p, &b)| {
                server.submit(Request {
                    prompt: p.clone(),
                    max_new: b,
                    deadline: None,
                })
            })
            .collect();
        let out = sessions
            .into_iter()
            .map(|s| {
                let r = s.collect();
                assert!(!r.rejected, "default queue bound must admit all");
                r.tokens
            })
            .collect();
        server.shutdown().expect("stats");
        out
    };

    let serial = serve(
        Backend::NativePacked(Box::new(SlabModel::from_packed(&params, &packed, 2))),
        ServerConfig::default(),
    );
    let scfg = ServerConfig {
        sched: slab::coordinator::SchedulerConfig {
            max_batch: 3, // smaller than the request count: forced churn
            ..Default::default()
        },
        ..Default::default()
    };
    let batched = serve(
        Backend::NativeBatched(Box::new(SlabModel::from_packed(&params, &packed, 2))),
        scfg,
    );
    assert_eq!(serial, batched, "continuous batcher diverged from serial packed serving");
    for (tokens, &b) in batched.iter().zip(budgets.iter()) {
        assert!(tokens.len() <= b.min(cfg.max_seq - cfg.prompt_len));
    }
}

#[test]
fn paged_scheduler_survives_churn_at_tiny_page_budgets() {
    // The PR-5 cancellation/deadline churn fuzz, rerun in
    // page-exhaustion regimes: a paged scheduler on a page budget
    // barely above the one-worst-case-session floor, under random
    // submit / cancel / instant-deadline / tick churn. Invariants:
    // the scheduler always drains (no deadlock), every stream carries
    // exactly one terminal event with no tokens after it, a rejected
    // request gets exactly one `Rejected` and nothing else, and every
    // token stream is a bit-exact prefix of the serial reference —
    // page pressure may shorten streams, never corrupt them.
    let cfg = native_test_cfg();
    let params = eos_free_params(&cfg, 0x51ab);
    let serial = SlabModel::from_dense(&params, 1);
    let headroom = cfg.max_seq - cfg.prompt_len;
    let prompt_pool: Vec<Vec<i32>> = vec![
        vec![5, 6, 7],
        vec![9, 10],
        vec![11, 12, 13, 14],
        vec![5, 6, 7, 8, 9, 10],
    ];
    let reference: Vec<Vec<i32>> = prompt_pool
        .iter()
        .map(|p| serial.generate_batch(&[p.clone()], headroom).remove(0))
        .collect();
    let seed = fuzz_seed(0xbadcafe);
    eprintln!("paged churn fuzz seed: {seed} (set SLAB_FUZZ_SEED to replay)");
    let mut rng = Pcg64::seed_from_u64(seed);

    struct Client {
        rx: Receiver<Event>,
        pidx: usize,
        budget: usize,
        cancel: Option<CancelHandle>,
    }

    for round in 0..4usize {
        // kv_page 2 → the floor is ⌈20/2⌉ = 10 pages; budgets barely
        // above it keep admission and decode permanently page-starved.
        let page_budget = 10 + rng.below_usize(8);
        let mut s = Scheduler::new(
            Box::new(SlabModel::from_dense(&params, 1)),
            SchedulerConfig {
                max_batch: 3,
                queue_cap: 4,
                kv_page: 2,
                page_budget,
                prefix_sharing: round % 2 == 0,
                ..Default::default()
            },
        );
        let mut clients: Vec<Client> = Vec::new();
        for _ in 0..60 {
            match rng.below(4) {
                0 | 1 => {
                    let pidx = rng.below_usize(prompt_pool.len());
                    let budget = 1 + rng.below_usize(headroom);
                    // 1-in-4 submissions carry an already-expired
                    // deadline: reaped from the queue or batch with a
                    // clean Evicted terminal.
                    let deadline = if rng.below(4) == 0 {
                        Some(Duration::ZERO)
                    } else {
                        None
                    };
                    let (tx, rx) = channel();
                    let cancel = s.enqueue(
                        Request {
                            prompt: prompt_pool[pidx].clone(),
                            max_new: budget,
                            deadline,
                        },
                        tx,
                    );
                    clients.push(Client {
                        rx,
                        pidx,
                        budget,
                        cancel,
                    });
                }
                2 => {
                    if !clients.is_empty() {
                        let i = rng.below_usize(clients.len());
                        if let Some(c) = &clients[i].cancel {
                            c.cancel();
                        }
                    }
                }
                _ => {
                    s.tick();
                }
            }
        }
        let mut drain = 0usize;
        while s.has_work() {
            s.tick();
            drain += 1;
            assert!(drain < 2000, "round {round}: scheduler failed to drain");
        }
        for (ci, c) in clients.iter().enumerate() {
            let rejected = c.cancel.is_none();
            let mut tokens: Vec<i32> = Vec::new();
            let mut terminals = 0usize;
            for ev in c.rx.try_iter() {
                match ev {
                    Event::Token(t) => {
                        assert_eq!(terminals, 0, "round {round} client {ci}: token after terminal");
                        tokens.push(t);
                    }
                    Event::Rejected => {
                        assert!(rejected, "round {round} client {ci}: spurious Rejected");
                        terminals += 1;
                    }
                    Event::Done(_) | Event::Evicted(_) => terminals += 1,
                }
            }
            assert_eq!(terminals, 1, "round {round} client {ci}: exactly one terminal");
            if rejected {
                assert!(tokens.is_empty(), "round {round} client {ci}: tokens on rejection");
                continue;
            }
            let want = &reference[c.pidx];
            assert!(tokens.len() <= c.budget);
            assert_eq!(
                tokens[..],
                want[..tokens.len()],
                "round {round} client {ci}: stream must be a prefix of the serial reference"
            );
        }
        let st = s.into_stats();
        assert!(
            st.kv_pages_peak <= page_budget,
            "round {round}: page budget is a hard ceiling"
        );
    }
}

#[test]
fn page_eviction_frees_pages_for_same_tick_admission() {
    // A release must make its pages admittable in the *same* tick
    // (reap → admit → decode ordering): a session blocked purely on
    // page availability is admitted and decoded the very tick the
    // page holder is cancelled — and still streams its exact serial
    // tokens off the recycled pages.
    let cfg = native_test_cfg();
    let params = eos_free_params(&cfg, 0x7a9e);
    let serial = SlabModel::from_dense(&params, 1);
    let reference_a = serial.generate_batch(&[vec![5, 6, 7]], 14).remove(0);
    let reference_b = serial.generate_batch(&[vec![9, 10]], 4).remove(0);
    let mut s = Scheduler::new(
        Box::new(SlabModel::from_dense(&params, 1)),
        SchedulerConfig {
            max_batch: 2,
            kv_page: 2,
            page_budget: 10, // exactly one worst-case session
            prefix_sharing: false,
            ..Default::default()
        },
    );
    let (tx_a, rx_a) = channel();
    let cancel_a = s
        .enqueue(
            Request {
                prompt: vec![5, 6, 7],
                max_new: 14,
                deadline: None,
            },
            tx_a,
        )
        .expect("queued");
    // Let A grow to 8 of the 10 pages (prompt 3 + one per 2 decodes).
    for _ in 0..9 {
        s.tick();
    }
    let (tx_b, rx_b) = channel();
    s.enqueue(
        Request {
            prompt: vec![9, 10],
            max_new: 4,
            deadline: None,
        },
        tx_b,
    )
    .expect("queued");
    s.tick();
    assert_eq!(
        (s.active_sessions(), s.queued()),
        (1, 1),
        "B must stall on page availability, not batch capacity"
    );
    cancel_a.cancel();
    let decoded = s.tick(); // reap A (pages freed) → admit B → decode B
    assert_eq!(decoded, 1, "B decoding the very tick A's pages freed");
    assert_eq!((s.active_sessions(), s.queued()), (1, 0));
    while s.has_work() {
        s.tick();
    }
    let ra = collect_events(&rx_a);
    assert!(ra.cancelled);
    assert!(!ra.tokens.is_empty());
    assert_eq!(ra.tokens[..], reference_a[..ra.tokens.len()]);
    let rb = collect_events(&rx_b);
    assert!(!rb.cancelled && !rb.evicted);
    assert_eq!(rb.tokens, reference_b, "B bit-exact off recycled pages");
    let st = s.into_stats();
    assert_eq!(st.page_evictions, 0, "blocking, not preemption, under admission pressure");
    assert_eq!(st.kv_pages, 0, "sharing off: every page returned");
    assert!(st.kv_pages_peak <= 10);
}

#[test]
fn refined_alloc_checkpoint_streams_reloads_and_serves_conformantly() {
    // ISSUE-10 acceptance e2e: a refine+alloc job streamed through the
    // CheckpointWriter reloads bit-identical to the keep-everything
    // run, serves token-identically across the three serve shapes
    // (contiguous KV, paged KV, speculative decode), and beats the
    // one-shot uniform job on activation-weighted error at an exactly
    // equal planned global budget.
    let cfg = native_test_cfg();
    let params = Params::init(&cfg, 0x10aa);
    let g = Grammar::standard();
    let corpus = build_corpus(&g, 11, 16, 8, 16, cfg.max_seq);
    let method = slab::baselines::Method::Slab(SlabConfig {
        iters: 2,
        svd_iters: 4,
        ..Default::default()
    });
    let rc = RefineConfig::with_rounds(2);

    let kept = CompressJob::new(&params, &corpus.calib, &method)
        .threads(0)
        .refine(rc)
        .budget(BudgetConfig::default())
        .run()
        .expect("refine+alloc job");
    let plan = kept.report.budget.as_ref().expect("plan recorded in report");
    assert_eq!(
        plan.total_keep(),
        plan.total_uniform_keep(),
        "allocator must conserve the global keep budget exactly"
    );
    assert_eq!(kept.report.refine.len(), cfg.pruned.len(), "one refine report per linear");

    // Same job streamed block-by-block: the checkpoint must reload
    // the exact packed layers the keep-everything run retained.
    let path = std::env::temp_dir().join("slab-tests/refined-alloc.slabckpt");
    let streamed = CompressJob::new(&params, &corpus.calib, &method)
        .threads(0)
        .refine(rc)
        .budget(BudgetConfig::default())
        .keep_dense(false)
        .keep_packed(false)
        .stream_to(path.clone())
        .run()
        .expect("streaming refine+alloc job");
    assert!(streamed.slab_layers.is_empty() && streamed.params.is_none());
    assert_eq!(streamed.report.layers, kept.report.layers, "streaming is emit-only");
    let reloaded = load_packed_checkpoint(&path).expect("reload streamed checkpoint");
    assert_eq!(reloaded, kept.slab_layers, "streamed checkpoint == retained layers");

    // Serve-path conformance over the reloaded model: contiguous KV,
    // paged KV, and self-speculative decode must stream the same
    // tokens (speculation is lossless by contract).
    let prompts: Vec<Vec<i32>> = vec![
        vec![5, 9, 14],
        vec![33, 34, 35, 36],
        vec![7],
        vec![40, 11, 22, 3, 8],
    ];
    let serve = |model: SlabModel, sched: SchedulerConfig| -> Vec<Vec<i32>> {
        let server = Server::start_with(
            Backend::NativeBatched(Box::new(model)),
            ServerConfig { sched, ..Default::default() },
        );
        let sessions: Vec<_> = prompts
            .iter()
            .map(|p| {
                server.submit(Request {
                    prompt: p.clone(),
                    max_new: 8,
                    deadline: None,
                })
            })
            .collect();
        let out = sessions.into_iter().map(|s| s.collect().tokens).collect();
        server.shutdown().expect("stats");
        out
    };
    let model = |layers: &[(String, SlabLayer)]| SlabModel::from_packed(&params, layers, 2);
    let contiguous = serve(
        model(&reloaded),
        SchedulerConfig { kv_page: 0, ..Default::default() },
    );
    let paged = serve(
        model(&reloaded),
        SchedulerConfig { kv_page: 2, page_budget: 64, ..Default::default() },
    );
    let speculative = serve(
        model(&reloaded),
        SchedulerConfig { speculate: true, draft_len: 3, ..Default::default() },
    );
    assert_eq!(contiguous, paged, "paged KV diverged on the refined checkpoint");
    assert_eq!(contiguous, speculative, "speculation diverged on the refined checkpoint");
    // And reloaded vs retained layers are interchangeable end to end.
    let retained = serve(
        model(&kept.slab_layers),
        SchedulerConfig { kv_page: 0, ..Default::default() },
    );
    assert_eq!(contiguous, retained, "reload must be token-identical to the kept run");

    // Equal-budget quality acceptance: the alloc+refined run's
    // activation-weighted errors (err_after) must beat the one-shot
    // uniform run's (a rounds=0 refine records the fit error without
    // changing the decomposition).
    let uniform = CompressJob::new(&params, &corpus.calib, &method)
        .threads(0)
        .refine(RefineConfig::with_rounds(0))
        .run()
        .expect("uniform one-shot job");
    let werr = |reports: &[(String, RefineReport)], after: bool| -> f64 {
        reports
            .iter()
            .map(|(_, r)| {
                let e = if after { r.err_after() } else { r.err_before() } as f64;
                e * e
            })
            .sum::<f64>()
            .sqrt()
    };
    let oneshot_werr = werr(&uniform.report.refine, false);
    let refined_werr = werr(&kept.report.refine, true);
    assert!(
        refined_werr < oneshot_werr,
        "alloc+refine must reduce weighted error: {refined_werr} vs one-shot {oneshot_werr}"
    );
    // The planned budget is conserved exactly (asserted above); the
    // realized kept counts may drift only by per-row flooring.
    let total = |layers: &[slab::coordinator::LayerReport]| -> usize {
        layers.iter().map(|l| l.kept).sum()
    };
    let (ka, ku) = (total(&kept.report.layers), total(&uniform.report.layers));
    assert!(
        (ka as f64 - ku as f64).abs() <= 0.02 * ku as f64,
        "realized kept drift beyond flooring: alloc {ka} vs uniform {ku}"
    );
}

#[test]
fn packed_layer_checkpoint_roundtrips_through_disk() {
    // The packed-bitplane checkpoint format survives a disk roundtrip
    // inside a multi-layer container (one prefix per linear).
    let cfg = native_test_cfg();
    let params = Params::init(&cfg, 81);
    let (packed, _) = compress_native(&params, 82);
    let mut ck = slab::tensor::Checkpoint::new();
    for (name, layer) in &packed {
        layer.save_into(&mut ck, name);
    }
    let path = std::env::temp_dir().join("slab-tests/native-layers.slabckpt");
    ck.save(&path).unwrap();
    let back = slab::tensor::Checkpoint::load(&path).unwrap();
    for (name, layer) in &packed {
        let l = SlabLayer::load_from(&back, name).expect(name);
        assert_eq!(&l, layer, "{name}");
    }
}
