//! Integration tests for the evaluation harness: the artifact-free
//! native engine (packed-format scoring, parallel-vs-serial
//! bit-identity, the sweep e2e) plus the artifact-gated cross-engine
//! conformance checks against the XLA `eval_nll_{cfg}` path
//! (DESIGN.md §11). Artifact-gated tests skip with a stderr note when
//! `artifacts/` is absent, like `integration.rs`.

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

mod common;

use common::{compress_native, native_test_cfg, runtime, task_test_cfg};
use slab::data::{build_corpus, Grammar, Task, TokenSet};
use slab::eval::native::{batched_nll, perplexity, zero_shot, EvalOptions};
use slab::eval::{self, ParamsOnDevice};
use slab::experiments::{sweep, SweepConfig};
use slab::model::{Params, SlabModel};
use slab::runtime::ModelCfg;
use slab::util::prop;
use slab::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// Artifact-free: the native engine on every fresh clone
// ---------------------------------------------------------------------------

#[test]
fn packed_engine_eval_schedule_invariance_and_dense_conformance() {
    // The tentpole contract on the *packed* engine: any
    // (threads, batch) schedule is bit-identical to serial batch-1,
    // and the packed NLL lands kernel-rounding-close to the dense
    // reconstruction of the same decomposition.
    let cfg = native_test_cfg();
    let params = Params::init(&cfg, 61);
    let (packed, swapped) = compress_native(&params, 62);
    let packed_model = SlabModel::from_packed(&params, &packed, 1);
    let dense_model = SlabModel::from_dense(&swapped, 1);
    let rows = TokenSet::synthetic(10, cfg.max_seq, cfg.vocab).to_rows();

    let serial = batched_nll(&packed_model, &rows, EvalOptions { batch: 1, threads: 1 });
    assert_eq!(serial.len(), rows.len());
    for (batch, threads) in [(4usize, 3usize), (3, 0), (16, 2)] {
        assert_eq!(
            batched_nll(&packed_model, &rows, EvalOptions { batch, threads }),
            serial,
            "batch {batch} threads {threads} must be bit-identical to serial"
        );
    }

    let dense = batched_nll(&dense_model, &rows, EvalOptions::default());
    for (i, ((pn, pc), (dn, dc))) in serial.iter().zip(dense.iter()).enumerate() {
        assert_eq!(pc, dc, "row {i} token count");
        assert!(
            (pn - dn).abs() <= 5e-3 * (1.0 + dn.abs()),
            "row {i}: packed {pn} vs dense-reconstruction {dn}"
        );
    }
}

#[test]
fn native_zero_shot_runs_all_suites_on_the_packed_engine() {
    // Task scoring end to end on a packed model, artifact-free: all
    // seven suites produce accuracies in [0, 1], the macro average
    // matches, and the row fan-out is invisible.
    let cfg = task_test_cfg();
    let params = Params::init(&cfg, 63);
    let (packed, _) = compress_native(&params, 64);
    let model = SlabModel::from_packed(&params, &packed, 1);
    let g = Grammar::standard();
    let suites: Vec<(Task, Vec<slab::data::TaskItem>)> = slab::data::ALL_TASKS
        .iter()
        .map(|t| (*t, t.generate(&g, 4, 17)))
        .collect();
    let serial = zero_shot(&model, &suites, EvalOptions { batch: 4, threads: 1 });
    let par = zero_shot(&model, &suites, EvalOptions { batch: 4, threads: 3 });
    assert_eq!(serial.0, par.0, "row fan-out changed a task accuracy");
    assert_eq!(serial.1, par.1);
    assert_eq!(serial.0.len(), 7);
    for (task, acc) in &serial.0 {
        assert!(
            (0.0..=1.0).contains(acc),
            "{}: accuracy {acc} out of range",
            task.name()
        );
    }
    let want = serial.0.iter().map(|(_, a)| a).sum::<f64>() / 7.0;
    assert!((serial.1 - want).abs() < 1e-12);
}

#[test]
fn sweep_quick_emits_full_paper_style_table_artifact_free() {
    // The acceptance-criterion e2e: SLaB vs the four baselines at one
    // ratio, perplexity + per-task zero-shot + macro average, computed
    // entirely on the native engine — and deterministic under re-runs.
    let mut scfg = SweepConfig::quick(7);
    scfg.model = ModelCfg::llama("sweep-test", 512, 16, 1, 4, 32, 48, 6);
    scfg.ratios = vec![0.5];
    scfg.valid_rows = 4;
    scfg.calib_rows = 4;
    scfg.task_items = 2;
    scfg.threads = 2;
    scfg.iters = 2;
    scfg.lowrank_rank = 1;
    let params = Params::init(&scfg.model, scfg.seed ^ 0x1417);
    let table = sweep(&scfg, &params).unwrap();
    assert_eq!(table.header.len(), 3 + 7 + 1, "Method/CR/ppl + 7 tasks + avg");
    assert_eq!(
        table.rows.len(),
        1 + 5 + 2,
        "dense anchor + five methods + the refined/allocated SLaB variants"
    );
    assert_eq!(table.rows[0][0], "Dense");
    let methods: Vec<&str> = table.rows.iter().map(|r| r[0].as_str()).collect();
    for want in ["SLaB", "Wanda", "SparseGPT", "Magnitude", "SLaB+refine", "SLaB+alloc"] {
        assert!(methods.contains(&want), "missing {want} in {methods:?}");
    }
    assert!(
        methods.iter().any(|m| m.starts_with("Sparse+LR")),
        "missing the naive sparse+low-rank baseline in {methods:?}"
    );
    for row in &table.rows {
        if row[2] == "infeasible" {
            continue; // an unrealizable budget renders, not aborts
        }
        let ppl: f64 = row[2].parse().unwrap();
        assert!(ppl.is_finite() && ppl > 1.0, "ppl {ppl}");
        for cell in &row[3..] {
            let acc: f64 = cell.parse().unwrap();
            assert!((0.0..=100.0).contains(&acc), "acc cell {cell}");
        }
    }
    // Bit-for-bit reproducible: the whole pipeline (corpus, capture,
    // decompose, packed serving, parallel eval) is deterministic.
    let again = sweep(&scfg, &params).unwrap();
    assert_eq!(table.rows, again.rows);
}

// ---------------------------------------------------------------------------
// Artifact-gated: cross-engine conformance against the XLA eval path
// ---------------------------------------------------------------------------

#[test]
fn native_nll_cross_checks_xla_eval_nll_rows() {
    // ISSUE-4 conformance: on the same rows, the native batched NLL
    // must reproduce the eval_nll artifact's per-row numbers within
    // 1e-4 relative (the engines differ only by f32 summation order)
    // with exactly equal token counts — property-tested over random
    // shards via util::prop.
    let Some((_guard, rt)) = runtime() else { return };
    let cfg = rt.manifest.config("small").unwrap().clone();
    let params = Params::init(&cfg, 41);
    let model = SlabModel::from_dense(&params, 2);
    let dev = ParamsOnDevice::upload(&rt, &params).unwrap();
    let width = cfg.max_seq + 1;
    let vocab = cfg.vocab;
    prop::check(
        "native-vs-xla-eval-nll",
        4,
        |rng| 1 + rng.below_usize(6),
        |&n| {
            let mut rng = Pcg64::seed_from_u64(1000 + n as u64);
            let rows: Vec<Vec<i32>> = (0..n)
                .map(|_| {
                    (0..width)
                        .map(|_| 4 + rng.below_usize(vocab - 4) as i32)
                        .collect()
                })
                .collect();
            let xla = eval::nll_rows(&rt, &cfg.name, &dev, &rows, width)
                .map_err(|e| e.to_string())?;
            let nat = batched_nll(&model, &rows, EvalOptions { batch: 3, threads: 2 });
            if xla.len() != nat.len() {
                return Err(format!("row count {} vs {}", xla.len(), nat.len()));
            }
            for (i, ((xn, xc), (nn, nc))) in xla.iter().zip(nat.iter()).enumerate() {
                if xc != nc {
                    return Err(format!("row {i}: count {xc} vs {nc}"));
                }
                let tol = 1e-4 * (1.0 + xn.abs());
                if (xn - nn).abs() > tol {
                    return Err(format!("row {i}: xla {xn} vs native {nn} (tol {tol:.2e})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn native_perplexity_cross_checks_xla_on_grammar_shard() {
    // Corpus-level conformance on real grammar text: both engines'
    // perplexities land within a tight relative band on the same
    // held-out shard.
    let Some((_guard, rt)) = runtime() else { return };
    let cfg = rt.manifest.config("small").unwrap().clone();
    let params = Params::init(&cfg, 43);
    let g = Grammar::standard();
    let corpus = build_corpus(&g, 11, 1, 8, 1, cfg.max_seq);
    let xla = eval::perplexity(&rt, &params, &corpus.valid).unwrap();
    let model = SlabModel::from_dense(&params, 2);
    let nat = perplexity(&model, &corpus.valid, EvalOptions::with_threads(0));
    assert!(
        (xla - nat).abs() <= 1e-3 * (1.0 + xla.abs()),
        "xla ppl {xla} vs native ppl {nat}"
    );
}
