//! Quickstart: decompose one weight matrix with SLaB and inspect what
//! you get — no artifacts needed (pure native path).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

// Clippy policy: the kernel/numeric code here deliberately uses
// explicit index loops, operator-named helpers (`Mat::add`), and
// `vec!` literals in tests; the style/complexity lints below fight
// that idiom, so they are allowed target-wide while CI's
// `clippy --all-targets -- -D warnings` enforces everything else.
// (Centralize into a `[lints.clippy]` manifest table once a
// Cargo.toml lands in-tree.)
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::manual_range_contains,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::useless_vec,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::module_inception,
    clippy::new_without_default
)]

use slab::slab::{decompose, ActStats, SlabConfig, SlabLayer};
use slab::tensor::{matmul_bt, Mat};
use slab::util::rng::Pcg64;

fn main() {
    // A fake "linear layer": weight (256 out, 512 in) + calibration
    // activations (1024 samples).
    let mut rng = Pcg64::seed_from_u64(7);
    let w = Mat::randn(256, 512, 0.02, &mut rng);
    let x = Mat::randn(1024, 512, 1.0, &mut rng);
    let stats = ActStats::from_activations(&x);

    // Decompose at 50% compression (paper defaults: rank 1, 20 iters,
    // groups (1, Din), FP16 accounting).
    let cfg = SlabConfig::default();
    let d = decompose(&w, &stats, &cfg).expect("decompose");

    println!("SLaB quickstart — W (256x512) at CR {:.0}%", cfg.cr * 100.0);
    println!("  keep fraction (Eq.10): {:.4}", cfg.keep_fraction(256, 512).unwrap());
    println!("  non-zeros kept in W_S: {} / {}", d.kept, w.numel());
    println!("  Frobenius error per iteration: {:?}",
        d.frob_trace.iter().map(|v| format!("{v:.4}")).collect::<Vec<_>>());

    // The packed deployment format.
    let layer = SlabLayer::from_decomposition(&d);
    let dense_bytes = w.numel() * 4;
    println!("  deployed bytes: {} (dense f32: {}, ratio {:.2}x)",
        layer.nbytes_deploy(), dense_bytes,
        dense_bytes as f64 / layer.nbytes_deploy() as f64);

    // Compressed forward ≡ dense forward with the reconstruction.
    let xb = Mat::randn(4, 512, 1.0, &mut rng);
    let y_packed = layer.forward(&xb);
    let y_dense = matmul_bt(&xb, &layer.reconstruct());
    println!("  packed-vs-dense forward max |Δ|: {:.2e}",
        y_packed.sub(&y_dense).max_abs());

    // Compare against plain Wanda at the same CR.
    let wanda = slab::baselines::wanda_prune(&w, &stats, 0.5, None);
    println!("  ‖W−Ŵ‖_F: SLaB {:.4} vs Wanda {:.4}",
        w.frob_dist(&d.reconstruct()), wanda.frob_err);
}
